//! Cluster shape: how D HBM stacks compose into one serving machine.
//!
//! ARTEMIS's token dataflow shards one inference across the banks of a
//! *single* stack; serving heavy traffic means scaling past it — the
//! direction PIM-GPT (multi-channel DIMM scale-out) and Atleus (manycore
//! transformer accelerators) take.  A [`ClusterConfig`] describes the
//! scale-out shape consumed by [`cluster`](crate::cluster): the stack
//! count, the placement scheme, and the stack-to-stack link parameters
//! (the inter-stack analogue of the intra-bank ring, see
//! DESIGN.md §Cluster-scale-out for the parameter provenance).

/// How the D stacks split the serving work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Each stack is a full replica owning whole sessions (weights
    /// duplicated, sessions routed at admission).
    DataParallel,
    /// The stacks form one pipeline: each owns a contiguous layer range
    /// ([`stack_groups`](crate::dataflow::stack_groups)), activations
    /// hop stack-to-stack between stages.
    PipelineParallel,
}

impl Placement {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "dp" | "data-parallel" => Some(Placement::DataParallel),
            "pp" | "pipeline-parallel" => Some(Placement::PipelineParallel),
            _ => None,
        }
    }
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Placement::DataParallel => write!(f, "dp"),
            Placement::PipelineParallel => write!(f, "pp"),
        }
    }
}

impl crate::util::cli::CliOption for Placement {
    const KIND: &'static str = "placement";
    const VALUES: &'static [&'static str] = &["dp", "pp"];
    fn parse_cli(s: &str) -> Option<Self> {
        Placement::parse(s)
    }
}

/// How a replica advances its simulated clock (`serve-gen --engine`).
///
/// Purely a wall-clock knob: both strategies run the *same* tick
/// sequence with the same costing, so every reported number — and the
/// run's state hash — is bit-identical between them (DESIGN.md
/// §Event-engine; enforced by `tests/engine_equivalence.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineStrategy {
    /// The reference driver: per-arrival `advance_to` loop, with a
    /// full admission scan on every tick.
    #[default]
    Tick,
    /// Next-event time advance: arrivals and tick boundaries merge
    /// through a heap, admission scans run only when an arrival or a
    /// capacity release could change their outcome, and
    /// batch-invariant decode cost pieces carry over between ticks.
    Event,
}

impl EngineStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "tick" => Some(EngineStrategy::Tick),
            "event" => Some(EngineStrategy::Event),
            _ => None,
        }
    }
}

impl std::fmt::Display for EngineStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineStrategy::Tick => write!(f, "tick"),
            EngineStrategy::Event => write!(f, "event"),
        }
    }
}

impl crate::util::cli::CliOption for EngineStrategy {
    const KIND: &'static str = "engine";
    const VALUES: &'static [&'static str] = &["tick", "event"];
    fn parse_cli(s: &str) -> Option<Self> {
        EngineStrategy::parse(s)
    }
}

/// Stack-to-stack link parameters (interposer / package hop).
///
/// Defaults model a 512-bit 64 GB/s point-to-point link — a quarter of
/// the intra-stack 256 GB/s aggregate (Section IV.C) — plus a fixed
/// package-crossing latency per hop; energy per bit is ~3.4x the
/// post-GSA on-module rate, the usual off-module escalation.  All four
/// knobs are overridable; the substitution is recorded in DESIGN.md
/// §Substitution-ledger.
#[derive(Debug, Clone, Copy)]
pub struct StackLinkParams {
    /// Link width, bits per beat.
    pub width_bits: u64,
    /// One beat, ns.
    pub beat_ns: f64,
    /// Fixed per-hop latency (SerDes + package crossing), ns.
    pub hop_ns: f64,
    /// Energy per bit crossing a stack boundary, pJ.
    pub e_pj_per_bit: f64,
}

impl Default for StackLinkParams {
    fn default() -> Self {
        Self { width_bits: 512, beat_ns: 1.0, hop_ns: 40.0, e_pj_per_bit: 4.0 }
    }
}

/// The cluster shape consumed by [`cluster::run_cluster`](crate::cluster).
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    /// Number of HBM stacks (D).
    pub stacks: u64,
    pub placement: Placement,
    pub link: StackLinkParams,
    /// Driver threads for advancing independent replicas in parallel
    /// (`0` = auto: one per replica, capped at the machine's available
    /// parallelism).  Purely a wall-clock knob: every thread count —
    /// including `1`, the serial path — produces bit-identical reports
    /// (DESIGN.md §Performance-engineering).
    pub threads: usize,
    /// Clock-advance strategy for every replica of the run — another
    /// pure wall-clock knob (DESIGN.md §Event-engine).
    pub engine: EngineStrategy,
}

impl ClusterConfig {
    pub fn new(stacks: u64, placement: Placement) -> Self {
        assert!(stacks > 0, "cluster needs at least one stack");
        Self {
            stacks,
            placement,
            link: StackLinkParams::default(),
            threads: 0,
            engine: EngineStrategy::Tick,
        }
    }

    /// Same shape with an explicit driver-thread count (`0` = auto).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Same shape with an explicit clock-advance strategy.
    pub fn with_engine(mut self, engine: EngineStrategy) -> Self {
        self.engine = engine;
        self
    }

    /// Same shape with explicit stack-to-stack link parameters (the
    /// design-search link axes override the defaults through here).
    pub fn with_link(mut self, link: StackLinkParams) -> Self {
        self.link = link;
        self
    }

    /// Short label, e.g. `dp x4`.
    pub fn label(&self) -> String {
        format!("{} x{}", self.placement, self.stacks)
    }
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self::new(1, Placement::DataParallel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_parse_round_trip() {
        for p in [Placement::DataParallel, Placement::PipelineParallel] {
            assert_eq!(Placement::parse(&p.to_string()), Some(p));
        }
        assert_eq!(Placement::parse("data-parallel"), Some(Placement::DataParallel));
        assert_eq!(Placement::parse("nope"), None);
    }

    #[test]
    fn default_link_is_slower_than_intra_stack() {
        // 512 bits / ns = 64 GB/s < the 256 GB/s intra-stack aggregate.
        let l = StackLinkParams::default();
        let gbps = l.width_bits as f64 / 8.0 / l.beat_ns;
        assert!(gbps < 256.0);
        assert!(l.hop_ns > 0.0);
    }

    #[test]
    fn cluster_label() {
        let c = ClusterConfig::new(4, Placement::PipelineParallel);
        assert_eq!(c.label(), "pp x4");
        assert_eq!(ClusterConfig::default().stacks, 1);
    }

    #[test]
    fn threads_default_to_auto_and_are_overridable() {
        assert_eq!(ClusterConfig::default().threads, 0, "0 = auto-size the driver pool");
        let c = ClusterConfig::new(4, Placement::DataParallel).with_threads(2);
        assert_eq!(c.threads, 2);
        assert_eq!(c.stacks, 4, "with_threads must not touch the shape");
    }

    #[test]
    fn with_link_overrides_only_the_link() {
        let link = StackLinkParams { hop_ns: 80.0, width_bits: 256, ..Default::default() };
        let c = ClusterConfig::new(4, Placement::PipelineParallel).with_link(link);
        assert_eq!(c.link.hop_ns, 80.0);
        assert_eq!(c.link.width_bits, 256);
        assert_eq!(c.stacks, 4, "with_link must not touch the shape");
        assert_eq!(c.link.beat_ns, StackLinkParams::default().beat_ns);
    }

    #[test]
    fn engine_parse_round_trip_and_default() {
        assert_eq!(ClusterConfig::default().engine, EngineStrategy::Tick);
        for e in [EngineStrategy::Tick, EngineStrategy::Event] {
            assert_eq!(EngineStrategy::parse(&e.to_string()), Some(e));
        }
        assert_eq!(EngineStrategy::parse("EVENT"), Some(EngineStrategy::Event));
        assert_eq!(EngineStrategy::parse("sideways"), None);
        let c = ClusterConfig::new(2, Placement::DataParallel)
            .with_engine(EngineStrategy::Event);
        assert_eq!(c.engine, EngineStrategy::Event);
        assert_eq!(c.stacks, 2, "with_engine must not touch the shape");
    }
}
