//! Transformer workload zoo (paper Table II).

/// Transformer architecture family — determines the op graph shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// Original encoder-decoder transformer [1].
    EncoderDecoder,
    /// BERT/ALBERT-style encoder-only stack + classifier.
    EncoderOnly,
    /// Vision transformer: encoder-only over patch embeddings + MLP head.
    Vit,
    /// OPT-style decoder-only (causal attention).
    DecoderOnly,
}

/// One Table II row.
#[derive(Debug, Clone)]
pub struct TransformerModel {
    pub name: String,
    pub arch: Arch,
    /// Total parameter count (reported, used for reporting only).
    pub params_m: f64,
    /// Encoder (and decoder, for EncoderDecoder) layer count L.
    pub layers: u32,
    /// Sequence length (token count) N.
    pub seq_len: u32,
    pub heads: u32,
    pub d_model: u32,
    pub d_ff: u32,
    /// FFN activation: ReLU for the classic FFN, GELU for BERT/ViT.
    pub gelu: bool,
}

impl TransformerModel {
    pub fn d_head(&self) -> u32 {
        self.d_model / self.heads
    }

    /// With a different sequence length (Fig. 12 scalability sweeps).
    pub fn with_seq_len(&self, n: u32) -> Self {
        let mut m = self.clone();
        m.seq_len = n;
        m.name = format!("{}@N{}", self.name, n);
        m
    }
}

/// The five Table II workloads.
#[derive(Debug, Clone)]
pub struct ModelZoo;

impl ModelZoo {
    pub fn transformer_base() -> TransformerModel {
        TransformerModel {
            name: "Transformer-base".into(),
            arch: Arch::EncoderDecoder,
            params_m: 52.0,
            layers: 2,
            seq_len: 128,
            heads: 8,
            d_model: 512,
            d_ff: 2048,
            gelu: false,
        }
    }

    pub fn bert_base() -> TransformerModel {
        TransformerModel {
            name: "BERT-base".into(),
            arch: Arch::EncoderOnly,
            params_m: 108.0,
            layers: 12,
            seq_len: 128,
            heads: 12,
            d_model: 768,
            d_ff: 3072,
            gelu: true,
        }
    }

    pub fn albert_base() -> TransformerModel {
        TransformerModel {
            name: "ALBERT-base".into(),
            arch: Arch::EncoderOnly,
            params_m: 12.0,
            layers: 12,
            seq_len: 128,
            heads: 12,
            d_model: 768,
            d_ff: 3072,
            gelu: true,
        }
    }

    pub fn vit_base() -> TransformerModel {
        TransformerModel {
            name: "ViT-base".into(),
            arch: Arch::Vit,
            params_m: 86.0,
            layers: 12,
            seq_len: 256,
            heads: 12,
            d_model: 768,
            d_ff: 3072,
            gelu: true,
        }
    }

    pub fn opt_350() -> TransformerModel {
        TransformerModel {
            name: "OPT-350".into(),
            arch: Arch::DecoderOnly,
            params_m: 350.0,
            layers: 12,
            seq_len: 2048,
            heads: 12,
            d_model: 768,
            d_ff: 3072,
            gelu: false,
        }
    }

    /// All five Table II workloads, paper order.
    pub fn all() -> Vec<TransformerModel> {
        vec![
            Self::transformer_base(),
            Self::bert_base(),
            Self::albert_base(),
            Self::vit_base(),
            Self::opt_350(),
        ]
    }

    pub fn by_name(name: &str) -> Option<TransformerModel> {
        Self::all()
            .into_iter()
            .find(|m| m.name.eq_ignore_ascii_case(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_five_models() {
        assert_eq!(ModelZoo::all().len(), 5);
    }

    #[test]
    fn d_head_divides() {
        for m in ModelZoo::all() {
            assert_eq!(m.d_model % m.heads, 0, "{}", m.name);
            assert_eq!(m.d_head() * m.heads, m.d_model);
        }
    }

    #[test]
    fn table2_values() {
        let b = ModelZoo::bert_base();
        assert_eq!(b.layers, 12);
        assert_eq!(b.seq_len, 128);
        assert_eq!(b.heads, 12);
        assert_eq!(b.d_model, 768);
        assert_eq!(b.d_ff, 3072);
        let o = ModelZoo::opt_350();
        assert_eq!(o.seq_len, 2048);
        assert_eq!(o.arch, Arch::DecoderOnly);
    }

    #[test]
    fn by_name_is_case_insensitive() {
        assert!(ModelZoo::by_name("bert-base").is_some());
        assert!(ModelZoo::by_name("nope").is_none());
    }

    #[test]
    fn with_seq_len_changes_only_n() {
        let m = ModelZoo::bert_base().with_seq_len(512);
        assert_eq!(m.seq_len, 512);
        assert_eq!(m.d_model, 768);
    }
}
