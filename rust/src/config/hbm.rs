//! HBM geometry, timing and energy parameters (paper Table I).

/// HBM module geometry — paper Table I, "Configuration" rows.
#[derive(Debug, Clone)]
pub struct HbmConfig {
    pub stacks: u64,
    pub channels_per_stack: u64,
    pub banks_per_channel: u64,
    pub subarrays_per_bank: u64,
    pub tiles_per_subarray: u64,
    pub rows_per_tile: u64,
    pub bits_per_row: u64,
    /// Inter-bank link width in bits (Section III.D.3: 256-bit link).
    pub link_bits: u64,
    /// Per-stack peak bandwidth, GB/s (Section IV.C: 256 GB/s).
    pub link_bandwidth_gbps: f64,
    pub timing: TimingParams,
    pub energy: EnergyParams,
}

impl Default for HbmConfig {
    fn default() -> Self {
        Self {
            stacks: 1,
            channels_per_stack: 8,
            banks_per_channel: 4,
            subarrays_per_bank: 128,
            tiles_per_subarray: 32,
            rows_per_tile: 256,
            bits_per_row: 256,
            link_bits: 256,
            link_bandwidth_gbps: 256.0,
            timing: TimingParams::default(),
            energy: EnergyParams::default(),
        }
    }
}

impl HbmConfig {
    /// Total banks across the module.
    pub fn banks_total(&self) -> u64 {
        self.stacks * self.channels_per_stack * self.banks_per_channel
    }

    /// Subarrays concurrently operable per bank: the open-bit-line
    /// organization activates only half the subarrays at a time
    /// (Section III.A.1).
    pub fn active_subarrays_per_bank(&self) -> u64 {
        self.subarrays_per_bank / 2
    }

    /// Row width of one subarray in bits (all tiles side by side).
    pub fn subarray_row_bits(&self) -> u64 {
        self.tiles_per_subarray * self.bits_per_row
    }

    /// MACs retired per subarray per MAC step: each of the 32 tiles
    /// performs 2 concurrent multiplies (Section III.A.1 — half the
    /// bit-lines to the bottom S/A set, half to the top).
    pub fn macs_per_subarray_step(&self) -> u64 {
        self.tiles_per_subarray * 2
    }

    /// Storage capacity in bytes (sanity checks only).
    pub fn capacity_bytes(&self) -> u64 {
        self.banks_total()
            * self.subarrays_per_bank
            * self.tiles_per_subarray
            * self.rows_per_tile
            * self.bits_per_row
            / 8
    }

    /// Inter-bank transfer time for `bits` over the shared 256-bit link
    /// at one beat per MOC-subcycle (conservative ring model, ns).
    pub fn link_transfer_ns(&self, bits: u64) -> f64 {
        let beats = bits.div_ceil(self.link_bits);
        beats as f64 * self.timing.link_beat_ns
    }
}

/// Timing parameters. One memory-operation cycle (MOC) is an
/// activate-activate-precharge (AAP) sequence; the paper's SPICE analysis
/// puts it at 17 ns (Section IV preamble).
#[derive(Debug, Clone)]
pub struct TimingParams {
    /// One MOC (AAP primitive), ns.
    pub moc_ns: f64,
    /// A stochastic multiply = 2 MOCs (copy both operands into the
    /// computational rows; AND forms combinationally via the ROC diodes).
    pub mocs_per_multiply: u64,
    /// MOMCAP charge-transfer step after each multiply, ns (Fig. 7: 1 ns
    /// charging per step).
    pub momcap_step_ns: f64,
    /// Per-subarray MAC step: 64 MACs in 48 ns (Section II.E headline):
    /// 2 MOCs (34 ns) + S_to_A transfer + margin.
    pub mac_step_ns: f64,
    /// Full A_to_B conversion (A_to_U + U_to_B), ns (Section III.B: 31 ns
    /// vs AGNI's 56 ns).
    pub a_to_b_ns: f64,
    /// One beat on the inter-bank 256-bit link, ns.
    pub link_beat_ns: f64,
    /// DRAM row write (restore phase dominated), ns.
    pub write_row_ns: f64,
}

impl Default for TimingParams {
    fn default() -> Self {
        Self {
            moc_ns: 17.0,
            mocs_per_multiply: 2,
            momcap_step_ns: 1.0,
            mac_step_ns: 48.0,
            a_to_b_ns: 31.0,
            link_beat_ns: 1.0,
            write_row_ns: 17.0,
        }
    }
}

impl TimingParams {
    /// Latency of one stochastic multiply (the paper's 34 ns headline).
    pub fn multiply_ns(&self) -> f64 {
        self.moc_ns * self.mocs_per_multiply as f64
    }
}

/// Energy parameters — paper Table I "Energy" rows (22 nm DRAM, HBM [12]).
#[derive(Debug, Clone)]
pub struct EnergyParams {
    /// ACTIVATE of one DRAM row in one bank, pJ.
    pub e_act_pj: f64,
    /// Row buffer -> global sense amps, pJ/bit.
    pub e_pre_gsa_pj_per_bit: f64,
    /// GSA -> DRAM I/O, pJ/bit.
    pub e_post_gsa_pj_per_bit: f64,
    /// DRAM I/O channel (to host), pJ/bit.
    pub e_io_pj_per_bit: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            e_act_pj: 909.0,
            e_pre_gsa_pj_per_bit: 1.51,
            e_post_gsa_pj_per_bit: 1.17,
            e_io_pj_per_bit: 0.80,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_matches_table1_geometry() {
        // Paper inconsistency — see DESIGN.md §Modeling-decisions, entry
        // "HBM capacity (8 GB vs 1 GiB)": Section III says "8GB HBM
        // module" but the Table I geometry (32 banks x 128 subarrays
        // x 32 tiles x 256 rows x 256 bits) works out to exactly 1 GiB.
        // We implement Table I as written.
        let c = HbmConfig::default();
        assert_eq!(c.capacity_bytes(), 1024 * 1024 * 1024);
    }

    #[test]
    fn multiply_is_34ns() {
        let t = TimingParams::default();
        assert_eq!(t.multiply_ns(), 34.0);
    }

    #[test]
    fn open_bitline_halves_subarrays() {
        let c = HbmConfig::default();
        assert_eq!(c.active_subarrays_per_bank(), 64);
    }

    #[test]
    fn subarray_step_is_64_macs() {
        let c = HbmConfig::default();
        assert_eq!(c.macs_per_subarray_step(), 64);
    }

    #[test]
    fn link_transfer_rounds_up() {
        let c = HbmConfig::default();
        assert_eq!(c.link_transfer_ns(1), c.timing.link_beat_ns);
        assert_eq!(c.link_transfer_ns(256), c.timing.link_beat_ns);
        assert_eq!(c.link_transfer_ns(257), 2.0 * c.timing.link_beat_ns);
    }
}
