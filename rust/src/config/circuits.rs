//! Per-subarray circuit overheads (paper Table III) and MOMCAP device
//! parameters (Section III.A.2 / Fig. 7).

/// Length of the stochastic bit-streams: signed 8-bit values are
/// represented as 128-bit TCU streams plus one sign bit (Section III.A.1).
pub const SC_STREAM_LEN: u32 = 128;

/// One synthesized NSC/tile circuit: latency, power, area (Table III).
#[derive(Debug, Clone, Copy)]
pub struct Circuit {
    pub latency_ps: f64,
    pub power_mw: f64,
    pub area_um2: f64,
}

impl Circuit {
    /// Energy of one operation at the stated latency/power, pJ.
    pub fn energy_pj(&self) -> f64 {
        // mW * ps = 1e-3 W * 1e-12 s = 1e-15 J = 1e-3 pJ
        self.power_mw * self.latency_ps * 1e-3
    }
}

/// Table III — ARTEMIS per-subarray hardware overhead.
#[derive(Debug, Clone)]
pub struct CircuitOverheads {
    pub s_to_b: Circuit,
    pub comparator: Circuit,
    pub adder_subtractor: Circuit,
    pub luts: Circuit,
    pub b_to_tcu: Circuit,
    pub latches: Circuit,
}

impl Default for CircuitOverheads {
    fn default() -> Self {
        Self {
            s_to_b: Circuit { latency_ps: 20_000.0, power_mw: 0.053, area_um2: 970.0 },
            comparator: Circuit { latency_ps: 623.7, power_mw: 0.055, area_um2: 0.0088 },
            adder_subtractor: Circuit { latency_ps: 719.95, power_mw: 0.0028, area_um2: 0.0055 },
            luts: Circuit { latency_ps: 222.5, power_mw: 4.21, area_um2: 4.79 },
            b_to_tcu: Circuit { latency_ps: 530.2, power_mw: 0.021, area_um2: 0.063 },
            latches: Circuit { latency_ps: 77.7, power_mw: 0.028, area_um2: 0.13 },
        }
    }
}

impl CircuitOverheads {
    /// Total added area per subarray, µm² (Table III column sum).
    pub fn total_area_um2(&self) -> f64 {
        self.s_to_b.area_um2
            + self.comparator.area_um2
            + self.adder_subtractor.area_um2
            + self.luts.area_um2
            + self.b_to_tcu.area_um2
            + self.latches.area_um2
    }

    pub fn rows(&self) -> Vec<(&'static str, Circuit)> {
        vec![
            ("S_to_B Circuits", self.s_to_b),
            ("Comparator", self.comparator),
            ("Adder/Subtractors", self.adder_subtractor),
            ("LUTs", self.luts),
            ("B_to_TCU Blocks", self.b_to_tcu),
            ("Latches", self.latches),
        ]
    }
}

/// MOMCAP device parameters (Section III.A.2, Fig. 7 analysis).
#[derive(Debug, Clone)]
pub struct MomcapParams {
    /// Chosen capacitance, pF (8 pF aligns with the 338 µm² tile area).
    pub capacitance_pf: f64,
    /// Supply voltage the S_to_A circuit charges toward, V.
    pub vdd: f64,
    /// Charging time per accumulation step, ns (Fig. 7: 1 ns).
    pub step_ns: f64,
    /// Consecutive 128-bit accumulations supported before saturation at
    /// the chosen capacitance (paper: 20 at 8 pF).
    pub max_accumulations: u32,
    /// MOMCAPs usable per operational tile: its own + the idle
    /// open-bit-line neighbour's (Fig. 4) => 40-MAC window.
    pub caps_per_op_tile: u32,
    /// DRAM tile footprint the MOMCAP must fit, µm².
    pub tile_area_um2: f64,
}

impl Default for MomcapParams {
    fn default() -> Self {
        Self {
            capacitance_pf: 8.0,
            vdd: 1.1,
            step_ns: 1.0,
            max_accumulations: 20,
            caps_per_op_tile: 2,
            tile_area_um2: 338.0,
        }
    }
}

impl MomcapParams {
    /// MAC window per operational tile before A_to_B conversion
    /// (Section III.A.2: "up to 40 MAC operations").
    pub fn tile_window(&self) -> u32 {
        self.max_accumulations * self.caps_per_op_tile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_energy_is_positive() {
        let c = CircuitOverheads::default();
        for (name, circ) in c.rows() {
            assert!(circ.energy_pj() > 0.0, "{name}");
        }
    }

    #[test]
    fn s_to_b_dominates_area() {
        // Table III: the S_to_B circuits are the big area item (970 µm²).
        let c = CircuitOverheads::default();
        assert!(c.s_to_b.area_um2 / c.total_area_um2() > 0.99);
    }

    #[test]
    fn momcap_window_is_40() {
        assert_eq!(MomcapParams::default().tile_window(), 40);
    }

    #[test]
    fn energy_units() {
        // 1 mW for 1000 ps = 1 pJ
        let c = Circuit { latency_ps: 1000.0, power_mw: 1.0, area_um2: 0.0 };
        assert!((c.energy_pj() - 1.0).abs() < 1e-12);
    }
}
