//! Architecture, circuit and workload configuration (paper Tables I–III).
//!
//! Every constant the simulator consumes lives here, with the table/figure
//! it comes from cited next to it.  Experiments can override the defaults
//! through small JSON files (parsed by `util::json` — the offline build
//! has no serde).

mod circuits;
mod cluster;
mod fidelity;
mod hbm;
mod models;
mod slo;

pub use circuits::{CircuitOverheads, MomcapParams, SC_STREAM_LEN};
pub use cluster::{ClusterConfig, EngineStrategy, Placement, StackLinkParams};
pub use fidelity::FidelityParams;
pub use hbm::{EnergyParams, HbmConfig, TimingParams};
pub use models::{Arch, ModelZoo, TransformerModel};
pub use slo::{SloSpec, SloTarget};

/// Top-level ARTEMIS configuration: architecture + circuits + policy.
///
/// # Examples
///
/// ```
/// use artemis::config::ArtemisConfig;
///
/// // Paper Table I defaults: 1 stack x 8 channels x 4 banks.
/// let cfg = ArtemisConfig::default();
/// assert_eq!(cfg.hbm.banks_total(), 32);
/// assert_eq!(cfg.power_budget_w, 60.0);
///
/// // Fig. 12 scalability sweeps scale stacks and the power budget.
/// let big = ArtemisConfig::with_stacks(4);
/// assert_eq!(big.hbm.banks_total(), 128);
///
/// // Configs round-trip through JSON (subset overrides supported).
/// let back = ArtemisConfig::from_json(&cfg.to_json()).unwrap();
/// assert_eq!(back.hbm.banks_total(), cfg.hbm.banks_total());
/// ```
#[derive(Debug, Clone)]
pub struct ArtemisConfig {
    pub hbm: HbmConfig,
    pub circuits: CircuitOverheads,
    pub momcap: MomcapParams,
    /// Power budget in watts (paper: 60 W, aligned with HBM budgets).
    pub power_budget_w: f64,
    /// Static module power (refresh, periphery, I/O idle), W.  Drawn for
    /// the whole run; the activation throttle budgets around it.
    pub static_power_w: f64,
    /// Model the positive/negative sign-split dual pass (Section III.C.1).
    pub sign_split_passes: bool,
    /// Fidelity-engine stream-length scaling shares (§Fidelity-engine).
    pub fidelity: FidelityParams,
}

impl Default for ArtemisConfig {
    fn default() -> Self {
        Self {
            hbm: HbmConfig::default(),
            circuits: CircuitOverheads::default(),
            momcap: MomcapParams::default(),
            power_budget_w: 60.0,
            static_power_w: 12.0,
            sign_split_passes: true,
            fidelity: FidelityParams::default(),
        }
    }
}

impl ArtemisConfig {
    /// Config with `n` HBM stacks (Fig. 12 scalability sweeps).  The
    /// power budget scales with the stack count — the paper notes that
    /// "power consumption can increase with more HBM stacks" while
    /// energy efficiency still improves.
    pub fn with_stacks(n: u64) -> Self {
        let mut c = Self::default();
        c.hbm.stacks = n;
        c.power_budget_w *= n as f64;
        c.static_power_w *= n as f64;
        c
    }

    /// Load overrides from a JSON file: any subset of the keys emitted by
    /// [`ArtemisConfig::to_json`] may be present; missing keys keep their
    /// defaults.
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let j = crate::util::json::Json::parse(text)
            .map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
        let mut c = Self::default();
        if let Some(h) = j.get("hbm") {
            let g = |k: &str, d: u64| h.get(k).and_then(|v| v.as_u64()).unwrap_or(d);
            c.hbm.stacks = g("stacks", c.hbm.stacks);
            c.hbm.channels_per_stack = g("channels_per_stack", c.hbm.channels_per_stack);
            c.hbm.banks_per_channel = g("banks_per_channel", c.hbm.banks_per_channel);
            c.hbm.subarrays_per_bank = g("subarrays_per_bank", c.hbm.subarrays_per_bank);
            c.hbm.tiles_per_subarray = g("tiles_per_subarray", c.hbm.tiles_per_subarray);
            c.hbm.rows_per_tile = g("rows_per_tile", c.hbm.rows_per_tile);
            c.hbm.bits_per_row = g("bits_per_row", c.hbm.bits_per_row);
            c.hbm.link_bits = g("link_bits", c.hbm.link_bits);
        }
        if let Some(m) = j.get("momcap") {
            if let Some(v) = m.get("capacitance_pf").and_then(|v| v.as_f64()) {
                c.momcap.capacitance_pf = v;
            }
            if let Some(v) = m.get("max_accumulations").and_then(|v| v.as_u64()) {
                c.momcap.max_accumulations = v as u32;
            }
        }
        if let Some(v) = j.get("power_budget_w").and_then(|v| v.as_f64()) {
            c.power_budget_w = v;
        }
        if let Some(f) = j.get("fidelity") {
            if let Some(v) = f.get("alpha_time").and_then(|v| v.as_f64()) {
                c.fidelity.alpha_time = v;
            }
            if let Some(v) = f.get("beta_energy").and_then(|v| v.as_f64()) {
                c.fidelity.beta_energy = v;
            }
            if let Some(v) = f.get("gold_stream_len").and_then(|v| v.as_u64()) {
                c.fidelity.gold_stream_len = v as u32;
            }
            if let Some(v) = f.get("gold_sigma").and_then(|v| v.as_f64()) {
                c.fidelity.gold_sigma = v;
            }
        }
        if let Some(v) = j.get("sign_split_passes").and_then(|v| v.as_bool()) {
            c.sign_split_passes = v;
        }
        Ok(c)
    }

    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "hbm",
                Json::obj(vec![
                    ("stacks", Json::Num(self.hbm.stacks as f64)),
                    ("channels_per_stack", Json::Num(self.hbm.channels_per_stack as f64)),
                    ("banks_per_channel", Json::Num(self.hbm.banks_per_channel as f64)),
                    ("subarrays_per_bank", Json::Num(self.hbm.subarrays_per_bank as f64)),
                    ("tiles_per_subarray", Json::Num(self.hbm.tiles_per_subarray as f64)),
                    ("rows_per_tile", Json::Num(self.hbm.rows_per_tile as f64)),
                    ("bits_per_row", Json::Num(self.hbm.bits_per_row as f64)),
                    ("link_bits", Json::Num(self.hbm.link_bits as f64)),
                ]),
            ),
            (
                "momcap",
                Json::obj(vec![
                    ("capacitance_pf", Json::Num(self.momcap.capacitance_pf)),
                    ("max_accumulations", Json::Num(self.momcap.max_accumulations as f64)),
                ]),
            ),
            ("power_budget_w", Json::Num(self.power_budget_w)),
            ("sign_split_passes", Json::Bool(self.sign_split_passes)),
            (
                "fidelity",
                Json::obj(vec![
                    ("alpha_time", Json::Num(self.fidelity.alpha_time)),
                    ("beta_energy", Json::Num(self.fidelity.beta_energy)),
                    ("gold_stream_len", Json::Num(self.fidelity.gold_stream_len as f64)),
                    ("gold_sigma", Json::Num(self.fidelity.gold_sigma)),
                ]),
            ),
        ])
        .pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_table1() {
        let c = ArtemisConfig::default();
        assert_eq!(c.hbm.stacks, 1);
        assert_eq!(c.hbm.channels_per_stack, 8);
        assert_eq!(c.hbm.banks_per_channel, 4);
        assert_eq!(c.hbm.subarrays_per_bank, 128);
        assert_eq!(c.hbm.tiles_per_subarray, 32);
        assert_eq!(c.hbm.rows_per_tile, 256);
        assert_eq!(c.hbm.bits_per_row, 256);
        assert_eq!(c.power_budget_w, 60.0);
    }

    #[test]
    fn json_roundtrip() {
        let c = ArtemisConfig::default();
        let j = c.to_json();
        let c2 = ArtemisConfig::from_json(&j).unwrap();
        assert_eq!(c2.hbm.banks_total(), c.hbm.banks_total());
        assert_eq!(c2.power_budget_w, c.power_budget_w);
        assert_eq!(c2.fidelity, c.fidelity);
    }

    #[test]
    fn fidelity_gold_override_survives_the_json_path() {
        // Daemon snapshots embed the resolved config as JSON; a
        // restored design-search candidate must keep its gold-tier
        // operating point bit-exactly.
        let mut c = ArtemisConfig::default();
        c.fidelity.gold_stream_len = 32;
        c.fidelity.gold_sigma = 1.5;
        let c2 = ArtemisConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.fidelity.gold_stream_len, 32);
        assert_eq!(c2.fidelity.gold_sigma.to_bits(), 1.5f64.to_bits());
    }

    #[test]
    fn with_stacks_scales_banks() {
        let c = ArtemisConfig::with_stacks(4);
        assert_eq!(c.hbm.banks_total(), 4 * 8 * 4);
    }
}
