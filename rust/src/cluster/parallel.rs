//! Scoped-thread replica pool for the cluster driver.
//!
//! Data-parallel replicas are independent between routing decisions:
//! each owns its sessions, clock, KV tracker and metrics, and the only
//! shared state is the cost cache (value-deterministic — see
//! [`sim::CostCache`](crate::sim::CostCache)).  The driver therefore
//! advances all replicas to each arrival time concurrently and only
//! serializes the routing decision itself.
//!
//! ## Protocol
//!
//! Workers are spawned once per run (no per-arrival thread spawns) and
//! own a static strided partition of the replicas.  Each *epoch*:
//!
//! 1. main publishes a command word (the f64 bits of the target time,
//!    `∞` for "run to completion", or a shutdown sentinel),
//! 2. the start barrier releases the workers,
//! 3. every worker advances its replicas to the target,
//! 4. the end barrier hands control back to main, which reads the live
//!    load snapshots **in replica-index order** and routes the arrival.
//!
//! A panic inside a worker's replica work is caught so the worker
//! still reaches the end barrier (otherwise main would park on a
//! `Barrier` that can never be satisfied — a silent hang instead of a
//! diagnostic); main detects it right after the epoch, shuts the pool
//! down, and resumes the unwind with the original payload.
//!
//! ## Determinism argument (DESIGN.md §Performance-engineering)
//!
//! Bit-identity with the serial driver holds because (a) each replica
//! executes exactly the same `advance_to`/`push`/`run_to_completion`
//! call sequence as in the serial loop — the partition only changes
//! *who* makes the calls, not their per-replica order; (b) replicas
//! share no mutable state except the cost cache, whose entries are a
//! pure function of the key; (c) the router runs on the main thread
//! only, after the end barrier, over loads gathered in index order;
//! (d) the final merge ([`aggregate_report`](crate::serve)) walks
//! replicas in index order.  Thread scheduling can therefore reorder
//! only *wall-clock* work, never a simulated number.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex, PoisonError};

use crate::serve::{Phase, PhaseProfile, PhaseTimer, ReplicaSim, Router, SessionSpec};

/// Command sentinel: all-ones is a quiet-NaN bit pattern that
/// `f64::to_bits` never produces for a (non-negative, finite or `∞`)
/// simulated timestamp.
const SHUTDOWN: u64 = u64::MAX;

/// Drive `replicas` through the `arrivals` sequence (nondecreasing
/// `(arrival_ns, id)` order) with `threads` workers; returns the
/// replicas (in their original index order) after every session has
/// been served.  `threads` must be >= 2 — the caller keeps the plain
/// serial loop for the single-threaded path.  Arrivals are consumed one
/// at a time on the main thread, so a lazy
/// [`TraceStream`](crate::serve::TraceStream) never materializes — the
/// pool only ever sees the current spec's timestamp.  The main-thread
/// routing sections (load gather + route decision) are charged to
/// `routing_profile` under `--features profiling`.
pub(crate) fn drive_parallel<'a, I: Iterator<Item = SessionSpec>>(
    replicas: Vec<ReplicaSim<'a>>,
    arrivals: I,
    router: &mut Router,
    threads: usize,
    routing_profile: &mut PhaseProfile,
) -> Vec<ReplicaSim<'a>> {
    let n = replicas.len();
    debug_assert!(threads >= 2, "serial driving belongs to the caller");
    let workers = threads.min(n).max(1);
    let cells: Vec<Mutex<ReplicaSim<'a>>> = replicas.into_iter().map(Mutex::new).collect();
    let start = Barrier::new(workers + 1);
    let end = Barrier::new(workers + 1);
    let command = AtomicU64::new(0);
    // First worker panic of the run (payload kept for re-throwing).
    let panicked: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);

    std::thread::scope(|s| {
        for w in 0..workers {
            let (cells, start, end, command, panicked) =
                (&cells, &start, &end, &command, &panicked);
            s.spawn(move || loop {
                start.wait();
                let cmd = command.load(Ordering::SeqCst);
                if cmd == SHUTDOWN {
                    break;
                }
                let t = f64::from_bits(cmd);
                // Catch panics so this worker still reaches the end
                // barrier; main re-throws after the epoch.  Poisoned
                // locks (a sibling panicked mid-epoch) are recovered —
                // the run is aborting anyway.
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    for cell in cells.iter().skip(w).step_by(workers) {
                        let mut r = cell.lock().unwrap_or_else(PoisonError::into_inner);
                        if t.is_infinite() {
                            r.run_to_completion();
                        } else {
                            r.advance_to(t);
                        }
                    }
                }));
                if let Err(payload) = outcome {
                    let mut slot = panicked.lock().unwrap_or_else(PoisonError::into_inner);
                    slot.get_or_insert(payload);
                }
                end.wait();
            });
        }

        // One epoch: publish the target, run the pool, then re-throw
        // any worker panic with its original payload (after releasing
        // the workers to exit, so the scope can join them).
        let epoch = |t_bits: u64| {
            command.store(t_bits, Ordering::SeqCst);
            start.wait();
            end.wait();
            let payload =
                panicked.lock().unwrap_or_else(PoisonError::into_inner).take();
            if let Some(payload) = payload {
                command.store(SHUTDOWN, Ordering::SeqCst);
                start.wait();
                resume_unwind(payload);
            }
        };
        for spec in arrivals {
            epoch(spec.arrival_ns.to_bits());
            // Route against live load, gathered in index order.
            let timer = PhaseTimer::start();
            let loads: Vec<_> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| c.lock().expect("replica lock").load(i))
                .collect();
            let pick = router.route(&loads);
            timer.stop(routing_profile, Phase::Routing);
            cells[pick].lock().expect("replica lock").push(spec);
        }
        // Drain epoch: everyone serves out their tail concurrently.
        epoch(f64::INFINITY.to_bits());
        // Shutdown: workers exit right after the start barrier.
        command.store(SHUTDOWN, Ordering::SeqCst);
        start.wait();
    });

    cells.into_iter().map(|c| c.into_inner().expect("replica lock")).collect()
}
