//! Incremental cluster campaign driver — `run_cluster`, sliced.
//!
//! The one-shot driver ([`run_cluster`](super::run_cluster)) owns the
//! whole timeline: it routes every arrival and then runs each replica
//! to completion before returning.  The serve daemon needs the same
//! run *resumable* — advance a bounded amount, answer a status or
//! snapshot request, advance again — so [`Campaign`] re-packages the
//! serial driving loop as an explicit state machine:
//!
//! * **Arrival phase** (`next_arrival < order.len()`): each
//!   [`Campaign::step`] advances every replica to the next arrival,
//!   routes it against live load, and hands it over — exactly one
//!   iteration of the one-shot serial loop.
//! * **Drain phase**: replicas run to completion in index order,
//!   `max_ticks` scheduler ticks at a time
//!   ([`ReplicaSim::step_ticks`]).
//!
//! Construction goes through [`build_replicas`](super::build_replicas)
//! and the final report through
//! [`assemble_report`](super::assemble_report) — the same code paths
//! as the one-shot driver — so a stepped campaign's report (and its
//! state hash) is bit-identical to `run_cluster`'s for the same
//! inputs, whatever step granularity drove it.  The driver is serial
//! by construction (each step is one bounded unit of work); thread
//! requests only affect the one-shot path, and never move a reported
//! bit there either.
//!
//! [`Campaign::snapshot_json`] / [`Campaign::restore_json`] serialize
//! the in-flight state — the two phase cursors, the router's
//! round-robin pointer, and every replica's full serving state
//! (DESIGN.md §Serve-daemon).  The trace (regenerated from the spec's
//! seed) and all pure-memoization state stay out of the snapshot; a
//! restored campaign continues the exact tick sequence and lands on
//! the same state hash as the uninterrupted run.

use crate::config::{ArtemisConfig, ClusterConfig, TransformerModel};
use crate::serve::{
    Phase, PhaseProfile, PhaseTimer, ReplicaSim, RoutePolicy, Router, SchedulerConfig,
    SessionSpec,
};
use crate::telemetry::{Trace, TraceConfig, TraceMeta};
use crate::util::json::{parse_u64_str, u64_str, Json};

use super::{assemble_report, build_replicas, ClusterReport};

/// A cluster serving run as an explicit, resumable state machine.
pub struct Campaign<'a> {
    replicas: Vec<ReplicaSim<'a>>,
    /// The trace in arrival order (`(arrival_ns, id)`-sorted).
    order: Vec<SessionSpec>,
    /// Arrivals already routed.
    next_arrival: usize,
    /// First replica not yet run to completion (drain phase).
    drain_cursor: usize,
    router: Router,
    cluster: ClusterConfig,
    sched: SchedulerConfig,
    route: RoutePolicy,
    cached: bool,
    /// Present iff telemetry was enabled at construction.
    tc: Option<TraceConfig>,
    routing_profile: PhaseProfile,
    model: &'a TransformerModel,
}

impl<'a> Campaign<'a> {
    /// Build the campaign (replicas, sorted arrival order, router).
    /// Telemetry is enabled up front when `tc` is given — a replica
    /// cannot start tracing mid-run.
    #[allow(clippy::too_many_arguments)] // run_cluster's knobs, unbundled
    pub fn new(
        cfg: &'a ArtemisConfig,
        model: &'a TransformerModel,
        trace: &[SessionSpec],
        cluster: &ClusterConfig,
        sched: &SchedulerConfig,
        route: RoutePolicy,
        cached: bool,
        tc: Option<&TraceConfig>,
    ) -> Self {
        assert!(cluster.stacks > 0, "cluster needs at least one stack");
        let mut replicas = build_replicas(cfg, model, cluster, sched, cached);
        if let Some(tc) = tc {
            for r in replicas.iter_mut() {
                r.enable_telemetry(tc);
            }
        }
        let mut order: Vec<SessionSpec> = trace.to_vec();
        order.sort_by(|a, b| a.arrival_ns.total_cmp(&b.arrival_ns).then(a.id.cmp(&b.id)));
        Self {
            replicas,
            order,
            next_arrival: 0,
            drain_cursor: 0,
            router: Router::new(route),
            cluster: *cluster,
            sched: sched.clone(),
            route,
            cached,
            tc: tc.cloned(),
            routing_profile: PhaseProfile::default(),
            model,
        }
    }

    /// Advance by one bounded unit of work: route the next arrival, or
    /// run up to `max_ticks` drain ticks on the current replica.
    /// Returns `false` once the campaign is complete (and stays
    /// `false`; stepping a finished campaign is a no-op).
    pub fn step(&mut self, max_ticks: u64) -> bool {
        if self.next_arrival < self.order.len() {
            let spec = self.order[self.next_arrival];
            for r in self.replicas.iter_mut() {
                r.advance_to(spec.arrival_ns);
            }
            let timer = PhaseTimer::start();
            let loads: Vec<_> =
                self.replicas.iter().enumerate().map(|(i, r)| r.load(i)).collect();
            let pick = self.router.route(&loads);
            timer.stop(&mut self.routing_profile, Phase::Routing);
            self.replicas[pick].push(spec);
            self.next_arrival += 1;
            return true;
        }
        while self.drain_cursor < self.replicas.len() {
            if self.replicas[self.drain_cursor].step_ticks(max_ticks) {
                return true;
            }
            self.drain_cursor += 1;
        }
        false
    }

    /// Whether every arrival is routed and every replica fully drained.
    pub fn is_done(&self) -> bool {
        self.next_arrival >= self.order.len()
            && self
                .replicas
                .iter()
                .skip(self.drain_cursor)
                .all(|r| !r.has_work())
    }

    /// `(arrivals routed, total arrivals)` — the daemon's progress line.
    pub fn progress(&self) -> (usize, usize) {
        (self.next_arrival, self.order.len())
    }

    /// The replicas, for live introspection (`trace-window`).
    pub fn replicas(&self) -> &[ReplicaSim<'a>] {
        &self.replicas
    }

    /// Run to completion and assemble the final report (and trace,
    /// when telemetry was enabled — `meta` must be `Some` exactly
    /// then, mirroring `run_cluster` vs `run_cluster_traced`).
    pub fn finish(mut self, meta: Option<&TraceMeta>) -> (ClusterReport, Option<Trace>) {
        while self.step(u64::MAX) {}
        let Campaign {
            replicas, cluster, sched, route, cached, tc, routing_profile, model, ..
        } = self;
        let tracing = match (&tc, meta) {
            (Some(tc), Some(m)) => Some((tc, m)),
            (None, None) => None,
            (Some(_), None) => panic!("traced campaign finished without trace meta"),
            (None, Some(_)) => panic!("trace meta passed to an untraced campaign"),
        };
        assemble_report(
            replicas,
            model,
            &cluster,
            &sched,
            route,
            cached,
            1,
            routing_profile,
            tracing,
        )
    }

    /// Serialize the in-flight campaign state: phase cursors, router
    /// round-robin pointer, every replica's serving state.  The trace
    /// itself is not carried — it regenerates from the spec's seed —
    /// and neither is the wall-clock phase profile.
    pub fn snapshot_json(&self) -> Json {
        Json::obj(vec![
            ("next_arrival", u64_str(self.next_arrival as u64)),
            ("drain_cursor", u64_str(self.drain_cursor as u64)),
            ("rr_next", u64_str(self.router.rr_next() as u64)),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(|r| r.snapshot_json()).collect()),
            ),
        ])
    }

    /// Overlay a snapshot onto a freshly built campaign.  The campaign
    /// must have been constructed from the same spec (same trace,
    /// cluster shape, and telemetry choice); shape mismatches error
    /// without mutating cursor state.
    pub fn restore_json(&mut self, j: &Json) -> Result<(), String> {
        let want = |name: &str| {
            j.get(name).ok_or_else(|| format!("campaign snapshot missing '{name}'"))
        };
        let next_arrival = parse_u64_str(want("next_arrival")?)
            .ok_or("bad campaign next_arrival")? as usize;
        let drain_cursor =
            parse_u64_str(want("drain_cursor")?).ok_or("bad campaign drain_cursor")? as usize;
        let rr_next = parse_u64_str(want("rr_next")?).ok_or("bad campaign rr_next")? as usize;
        if next_arrival > self.order.len() {
            return Err(format!(
                "snapshot routed {next_arrival} arrivals, trace has {}",
                self.order.len()
            ));
        }
        if drain_cursor > self.replicas.len() {
            return Err(format!(
                "snapshot drain cursor {drain_cursor} exceeds {} replicas",
                self.replicas.len()
            ));
        }
        let reps = want("replicas")?
            .as_arr()
            .ok_or("campaign snapshot 'replicas' must be an array")?;
        if reps.len() != self.replicas.len() {
            return Err(format!(
                "snapshot has {} replicas, campaign has {}",
                reps.len(),
                self.replicas.len()
            ));
        }
        for (i, (r, rj)) in self.replicas.iter_mut().zip(reps.iter()).enumerate() {
            r.restore_json(rj).map_err(|e| format!("replica {i}: {e}"))?;
        }
        self.router.set_rr_next(rr_next);
        self.next_arrival = next_arrival;
        self.drain_cursor = drain_cursor;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::run_cluster;
    use super::*;
    use crate::config::{ArtemisConfig, EngineStrategy, Placement};
    use crate::config::ModelZoo;
    use crate::serve::{Policy, Scenario};

    fn setup(n: usize) -> (ArtemisConfig, TransformerModel, Vec<SessionSpec>, SchedulerConfig) {
        let cfg = ArtemisConfig::default();
        let model = ModelZoo::transformer_base(); // 2 layers: fast sim
        let trace = Scenario::chat().with_sessions(n).generate(1);
        let sched = SchedulerConfig { max_batch: 4, policy: Policy::Fifo };
        (cfg, model, trace, sched)
    }

    #[test]
    fn stepped_campaign_matches_one_shot_driver_bit_for_bit() {
        let (cfg, model, trace, sched) = setup(8);
        for placement in [Placement::DataParallel, Placement::PipelineParallel] {
            for engine in [EngineStrategy::Tick, EngineStrategy::Event] {
                let cl = ClusterConfig::new(2, placement).with_engine(engine);
                let reference = run_cluster(
                    &cfg,
                    &model,
                    &trace,
                    &cl,
                    &sched,
                    RoutePolicy::RoundRobin,
                    true,
                );
                let mut c = Campaign::new(
                    &cfg,
                    &model,
                    &trace,
                    &cl,
                    &sched,
                    RoutePolicy::RoundRobin,
                    true,
                    None,
                );
                // Deliberately tiny slices: granularity must not matter.
                let mut steps = 0usize;
                while c.step(3) {
                    steps += 1;
                    assert!(steps < 1_000_000, "campaign never finished");
                }
                assert!(c.is_done());
                let (r, doc) = c.finish(None);
                assert!(doc.is_none());
                assert_eq!(
                    r.state_hash(),
                    reference.state_hash(),
                    "{placement}/{engine}"
                );
                assert_eq!(r.aggregate.ticks, reference.aggregate.ticks);
                assert_eq!(
                    r.aggregate.makespan_ns.to_bits(),
                    reference.aggregate.makespan_ns.to_bits()
                );
            }
        }
    }

    #[test]
    fn snapshot_restore_resumes_to_identical_state_hash() {
        let (cfg, model, trace, sched) = setup(10);
        for placement in [Placement::DataParallel, Placement::PipelineParallel] {
            let cl = ClusterConfig::new(2, placement).with_engine(EngineStrategy::Event);
            let route = RoutePolicy::RoundRobin;
            let reference =
                run_cluster(&cfg, &model, &trace, &cl, &sched, route, true).state_hash();

            // Drive half-way (into the drain for dp, mid-arrivals is
            // covered by the smaller step count on pp), snapshot, and
            // round-trip the snapshot through its serialized text.
            let mut first = Campaign::new(&cfg, &model, &trace, &cl, &sched, route, true, None);
            let budget = if placement == Placement::DataParallel { 14 } else { 6 };
            for _ in 0..budget {
                if !first.step(2) {
                    break;
                }
            }
            let snap = Json::parse(&first.snapshot_json().compact()).expect("snapshot parses");

            let mut resumed =
                Campaign::new(&cfg, &model, &trace, &cl, &sched, route, true, None);
            resumed.restore_json(&snap).expect("restore");
            let (r, _) = resumed.finish(None);
            assert_eq!(r.state_hash(), reference, "{placement}");

            // The interrupted original also finishes to the same hash.
            let (orig, _) = first.finish(None);
            assert_eq!(orig.state_hash(), reference, "{placement} original");
        }
    }

    #[test]
    fn restore_rejects_shape_mismatches() {
        let (cfg, model, trace, sched) = setup(4);
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let donor = Campaign::new(
            &cfg,
            &model,
            &trace,
            &cl,
            &sched,
            RoutePolicy::RoundRobin,
            true,
            None,
        );
        let snap = donor.snapshot_json();
        // A 3-stack campaign cannot absorb a 2-stack snapshot.
        let cl3 = ClusterConfig::new(3, Placement::DataParallel);
        let mut other = Campaign::new(
            &cfg,
            &model,
            &trace,
            &cl3,
            &sched,
            RoutePolicy::RoundRobin,
            true,
            None,
        );
        let err = other.restore_json(&snap).unwrap_err();
        assert!(err.contains("replicas"), "{err}");
    }
}
