//! Incremental cluster campaign driver — `run_cluster`, sliced.
//!
//! The one-shot driver ([`run_cluster`](super::run_cluster)) owns the
//! whole timeline: it routes every arrival and then runs each replica
//! to completion before returning.  The serve daemon needs the same
//! run *resumable* — advance a bounded amount, answer a status or
//! snapshot request, advance again — so [`Campaign`] re-packages the
//! serial driving loop as an explicit state machine:
//!
//! * **Arrival phase** (arrivals remain): each [`Campaign::step`]
//!   advances every replica to the next arrival, routes it against
//!   live load, and hands it over — exactly one iteration of the
//!   one-shot serial loop.  Arrivals come from a materialized slice
//!   ([`Campaign::new`]) or a lazy seeded stream
//!   ([`Campaign::new_streamed`]) — the routed sequence is identical.
//! * **Drain phase**: replicas run to completion in index order,
//!   `max_ticks` scheduler ticks at a time
//!   ([`ReplicaSim::step_ticks`]).
//!
//! Construction goes through [`build_replicas`](super::build_replicas)
//! and the final report through
//! [`assemble_report`](super::assemble_report) — the same code paths
//! as the one-shot driver — so a stepped campaign's report (and its
//! state hash) is bit-identical to `run_cluster`'s for the same
//! inputs, whatever step granularity drove it.  The driver is serial
//! by construction (each step is one bounded unit of work); thread
//! requests only affect the one-shot path, and never move a reported
//! bit there either.
//!
//! [`Campaign::snapshot_json`] / [`Campaign::restore_json`] serialize
//! the in-flight state — the two phase cursors, the router's
//! round-robin pointer, and every replica's full serving state
//! (DESIGN.md §Serve-daemon).  The trace (regenerated from the spec's
//! seed) and all pure-memoization state stay out of the snapshot; a
//! restored campaign continues the exact tick sequence and lands on
//! the same state hash as the uninterrupted run.

use std::borrow::Cow;

use crate::config::{ArtemisConfig, ClusterConfig, TransformerModel};
use crate::serve::{
    is_arrival_sorted, Phase, PhaseProfile, PhaseTimer, ReplicaSim, RoutePolicy, Router,
    SchedulerConfig, SessionSpec, TraceCursor, TraceStream,
};
use crate::telemetry::{Trace, TraceConfig, TraceMeta};
use crate::util::json::{f64_bits, parse_f64_bits, parse_u64_str, u64_str, Json};

use super::{assemble_report, build_replicas, ClusterReport};

/// Where a campaign's arrivals come from: a materialized trace slice
/// (borrowed when already `(arrival, id)`-sorted, cloned only to sort)
/// or a lazy seeded [`TraceStream`] whose cursor travels with
/// snapshots.  Either way the routed sequence is identical.
enum Arrivals<'a> {
    Order { order: Cow<'a, [SessionSpec]>, next: usize },
    Stream { stream: TraceStream },
}

impl Arrivals<'_> {
    fn next(&mut self) -> Option<SessionSpec> {
        match self {
            Arrivals::Order { order, next } => {
                let s = order.get(*next).copied();
                if s.is_some() {
                    *next += 1;
                }
                s
            }
            Arrivals::Stream { stream } => stream.next(),
        }
    }

    /// Arrivals already routed.
    fn routed(&self) -> usize {
        match self {
            Arrivals::Order { next, .. } => *next,
            Arrivals::Stream { stream } => stream.emitted() as usize,
        }
    }

    /// Total arrivals the campaign will route.
    fn total(&self) -> usize {
        match self {
            Arrivals::Order { order, .. } => order.len(),
            Arrivals::Stream { stream } => stream.total() as usize,
        }
    }
}

fn cursor_to_json(c: &TraceCursor) -> Json {
    Json::obj(vec![
        ("rng", u64_str(c.rng_state)),
        ("t_ns", f64_bits(c.t_ns)),
        ("next_id", u64_str(c.next_id)),
    ])
}

fn cursor_from_json(j: &Json) -> Option<TraceCursor> {
    Some(TraceCursor {
        rng_state: parse_u64_str(j.get("rng")?)?,
        t_ns: parse_f64_bits(j.get("t_ns")?)?,
        next_id: parse_u64_str(j.get("next_id")?)?,
    })
}

/// A cluster serving run as an explicit, resumable state machine.
pub struct Campaign<'a> {
    replicas: Vec<ReplicaSim<'a>>,
    /// The arrival sequence in `(arrival_ns, id)` order.
    arrivals: Arrivals<'a>,
    /// First replica not yet run to completion (drain phase).
    drain_cursor: usize,
    router: Router,
    cluster: ClusterConfig,
    sched: SchedulerConfig,
    route: RoutePolicy,
    cached: bool,
    /// Present iff telemetry was enabled at construction.
    tc: Option<TraceConfig>,
    routing_profile: PhaseProfile,
    model: &'a TransformerModel,
}

impl<'a> Campaign<'a> {
    /// Build the campaign (replicas, sorted arrival order, router).
    /// Telemetry is enabled up front when `tc` is given — a replica
    /// cannot start tracing mid-run.
    #[allow(clippy::too_many_arguments)] // run_cluster's knobs, unbundled
    pub fn new(
        cfg: &'a ArtemisConfig,
        model: &'a TransformerModel,
        trace: &'a [SessionSpec],
        cluster: &ClusterConfig,
        sched: &SchedulerConfig,
        route: RoutePolicy,
        cached: bool,
        tc: Option<&TraceConfig>,
    ) -> Self {
        // Generated traces arrive sorted: borrow them; clone-and-sort
        // only genuinely unordered input.
        let order = if is_arrival_sorted(trace) {
            Cow::Borrowed(trace)
        } else {
            let mut v = trace.to_vec();
            v.sort_by(|a, b| a.arrival_ns.total_cmp(&b.arrival_ns).then(a.id.cmp(&b.id)));
            Cow::Owned(v)
        };
        Self::with_arrivals(
            cfg,
            model,
            Arrivals::Order { order, next: 0 },
            cluster,
            sched,
            route,
            cached,
            tc,
        )
    }

    /// [`Campaign::new`] over a lazy arrival stream: the trace is never
    /// materialized — arrivals are pulled one at a time and the stream
    /// cursor (RNG state, clock, next id) travels with snapshots, so a
    /// restored campaign resumes mid-stream bit-identically.
    #[allow(clippy::too_many_arguments)] // run_cluster's knobs, unbundled
    pub fn new_streamed(
        cfg: &'a ArtemisConfig,
        model: &'a TransformerModel,
        stream: TraceStream,
        cluster: &ClusterConfig,
        sched: &SchedulerConfig,
        route: RoutePolicy,
        cached: bool,
        tc: Option<&TraceConfig>,
    ) -> Self {
        Self::with_arrivals(
            cfg,
            model,
            Arrivals::Stream { stream },
            cluster,
            sched,
            route,
            cached,
            tc,
        )
    }

    #[allow(clippy::too_many_arguments)] // run_cluster's knobs, unbundled
    fn with_arrivals(
        cfg: &'a ArtemisConfig,
        model: &'a TransformerModel,
        arrivals: Arrivals<'a>,
        cluster: &ClusterConfig,
        sched: &SchedulerConfig,
        route: RoutePolicy,
        cached: bool,
        tc: Option<&TraceConfig>,
    ) -> Self {
        assert!(cluster.stacks > 0, "cluster needs at least one stack");
        let mut replicas = build_replicas(cfg, model, cluster, sched, cached);
        if let Some(tc) = tc {
            for r in replicas.iter_mut() {
                r.enable_telemetry(tc);
            }
        }
        Self {
            replicas,
            arrivals,
            drain_cursor: 0,
            router: Router::new(route),
            cluster: *cluster,
            sched: sched.clone(),
            route,
            cached,
            tc: tc.cloned(),
            routing_profile: PhaseProfile::default(),
            model,
        }
    }

    /// Advance by one bounded unit of work: route the next arrival, or
    /// run up to `max_ticks` drain ticks on the current replica.
    /// Returns `false` once the campaign is complete (and stays
    /// `false`; stepping a finished campaign is a no-op).
    pub fn step(&mut self, max_ticks: u64) -> bool {
        if let Some(spec) = self.arrivals.next() {
            for r in self.replicas.iter_mut() {
                r.advance_to(spec.arrival_ns);
            }
            let timer = PhaseTimer::start();
            let loads: Vec<_> =
                self.replicas.iter().enumerate().map(|(i, r)| r.load(i)).collect();
            let pick = self.router.route(&loads);
            timer.stop(&mut self.routing_profile, Phase::Routing);
            self.replicas[pick].push(spec);
            return true;
        }
        while self.drain_cursor < self.replicas.len() {
            if self.replicas[self.drain_cursor].step_ticks(max_ticks) {
                return true;
            }
            self.drain_cursor += 1;
        }
        false
    }

    /// Whether every arrival is routed and every replica fully drained.
    pub fn is_done(&self) -> bool {
        self.arrivals.routed() >= self.arrivals.total()
            && self
                .replicas
                .iter()
                .skip(self.drain_cursor)
                .all(|r| !r.has_work())
    }

    /// `(arrivals routed, total arrivals)` — the daemon's progress line.
    pub fn progress(&self) -> (usize, usize) {
        (self.arrivals.routed(), self.arrivals.total())
    }

    /// The replicas, for live introspection (`trace-window`).
    pub fn replicas(&self) -> &[ReplicaSim<'a>] {
        &self.replicas
    }

    /// Run to completion and assemble the final report (and trace,
    /// when telemetry was enabled — `meta` must be `Some` exactly
    /// then, mirroring `run_cluster` vs `run_cluster_traced`).
    pub fn finish(mut self, meta: Option<&TraceMeta>) -> (ClusterReport, Option<Trace>) {
        while self.step(u64::MAX) {}
        let Campaign {
            replicas, cluster, sched, route, cached, tc, routing_profile, model, ..
        } = self;
        let tracing = match (&tc, meta) {
            (Some(tc), Some(m)) => Some((tc, m)),
            (None, None) => None,
            (Some(_), None) => panic!("traced campaign finished without trace meta"),
            (None, Some(_)) => panic!("trace meta passed to an untraced campaign"),
        };
        assemble_report(
            replicas,
            model,
            &cluster,
            &sched,
            route,
            cached,
            1,
            routing_profile,
            tracing,
        )
    }

    /// Serialize the in-flight campaign state: phase cursors, router
    /// round-robin pointer, the stream cursor (RNG state, clock, next
    /// id) for streamed campaigns, every replica's serving state.  A
    /// materialized trace is not carried — it regenerates from the
    /// spec's seed — and neither is the wall-clock phase profile.
    pub fn snapshot_json(&self) -> Json {
        let stream = match &self.arrivals {
            Arrivals::Order { .. } => Json::Null,
            Arrivals::Stream { stream } => cursor_to_json(&stream.cursor()),
        };
        Json::obj(vec![
            ("next_arrival", u64_str(self.arrivals.routed() as u64)),
            ("drain_cursor", u64_str(self.drain_cursor as u64)),
            ("rr_next", u64_str(self.router.rr_next() as u64)),
            ("stream", stream),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(|r| r.snapshot_json()).collect()),
            ),
        ])
    }

    /// Overlay a snapshot onto a freshly built campaign.  The campaign
    /// must have been constructed from the same spec (same trace or
    /// stream, cluster shape, and telemetry choice); shape mismatches
    /// error without mutating cursor state.
    pub fn restore_json(&mut self, j: &Json) -> Result<(), String> {
        let want = |name: &str| {
            j.get(name).ok_or_else(|| format!("campaign snapshot missing '{name}'"))
        };
        let next_arrival = parse_u64_str(want("next_arrival")?)
            .ok_or("bad campaign next_arrival")? as usize;
        let drain_cursor =
            parse_u64_str(want("drain_cursor")?).ok_or("bad campaign drain_cursor")? as usize;
        let rr_next = parse_u64_str(want("rr_next")?).ok_or("bad campaign rr_next")? as usize;
        if next_arrival > self.arrivals.total() {
            return Err(format!(
                "snapshot routed {next_arrival} arrivals, trace has {}",
                self.arrivals.total()
            ));
        }
        if drain_cursor > self.replicas.len() {
            return Err(format!(
                "snapshot drain cursor {drain_cursor} exceeds {} replicas",
                self.replicas.len()
            ));
        }
        // Validate the stream cursor before touching any state.
        let stream_j = j.get("stream");
        let cursor = match (&self.arrivals, stream_j) {
            (Arrivals::Stream { .. }, Some(sj)) if !matches!(sj, Json::Null) => {
                let cur = cursor_from_json(sj).ok_or("bad campaign stream cursor")?;
                if cur.next_id != next_arrival as u64 {
                    return Err(format!(
                        "stream cursor at id {} but snapshot routed {next_arrival} arrivals",
                        cur.next_id
                    ));
                }
                Some(cur)
            }
            (Arrivals::Stream { .. }, _) => {
                return Err("campaign snapshot missing 'stream' cursor".into());
            }
            (Arrivals::Order { .. }, Some(sj)) if !matches!(sj, Json::Null) => {
                return Err(
                    "campaign snapshot carries a stream cursor but the campaign was built \
                     from a materialized trace"
                        .into(),
                );
            }
            (Arrivals::Order { .. }, _) => None,
        };
        let reps = want("replicas")?
            .as_arr()
            .ok_or("campaign snapshot 'replicas' must be an array")?;
        if reps.len() != self.replicas.len() {
            return Err(format!(
                "snapshot has {} replicas, campaign has {}",
                reps.len(),
                self.replicas.len()
            ));
        }
        for (i, (r, rj)) in self.replicas.iter_mut().zip(reps.iter()).enumerate() {
            r.restore_json(rj).map_err(|e| format!("replica {i}: {e}"))?;
        }
        self.router.set_rr_next(rr_next);
        match (&mut self.arrivals, cursor) {
            (Arrivals::Order { next, .. }, None) => *next = next_arrival,
            (Arrivals::Stream { stream }, Some(cur)) => stream.seek(cur),
            _ => unreachable!("cursor validated against the arrivals variant above"),
        }
        self.drain_cursor = drain_cursor;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::run_cluster;
    use super::*;
    use crate::config::{ArtemisConfig, EngineStrategy, Placement};
    use crate::config::ModelZoo;
    use crate::serve::{Policy, Scenario};

    fn setup(n: usize) -> (ArtemisConfig, TransformerModel, Vec<SessionSpec>, SchedulerConfig) {
        let cfg = ArtemisConfig::default();
        let model = ModelZoo::transformer_base(); // 2 layers: fast sim
        let trace = Scenario::chat().with_sessions(n).generate(1);
        let sched = SchedulerConfig { max_batch: 4, policy: Policy::Fifo };
        (cfg, model, trace, sched)
    }

    #[test]
    fn stepped_campaign_matches_one_shot_driver_bit_for_bit() {
        let (cfg, model, trace, sched) = setup(8);
        for placement in [Placement::DataParallel, Placement::PipelineParallel] {
            for engine in [EngineStrategy::Tick, EngineStrategy::Event] {
                let cl = ClusterConfig::new(2, placement).with_engine(engine);
                let reference = run_cluster(
                    &cfg,
                    &model,
                    &trace,
                    &cl,
                    &sched,
                    RoutePolicy::RoundRobin,
                    true,
                );
                let mut c = Campaign::new(
                    &cfg,
                    &model,
                    &trace,
                    &cl,
                    &sched,
                    RoutePolicy::RoundRobin,
                    true,
                    None,
                );
                // Deliberately tiny slices: granularity must not matter.
                let mut steps = 0usize;
                while c.step(3) {
                    steps += 1;
                    assert!(steps < 1_000_000, "campaign never finished");
                }
                assert!(c.is_done());
                let (r, doc) = c.finish(None);
                assert!(doc.is_none());
                assert_eq!(
                    r.state_hash(),
                    reference.state_hash(),
                    "{placement}/{engine}"
                );
                assert_eq!(r.aggregate.ticks, reference.aggregate.ticks);
                assert_eq!(
                    r.aggregate.makespan_ns.to_bits(),
                    reference.aggregate.makespan_ns.to_bits()
                );
            }
        }
    }

    #[test]
    fn snapshot_restore_resumes_to_identical_state_hash() {
        let (cfg, model, trace, sched) = setup(10);
        for placement in [Placement::DataParallel, Placement::PipelineParallel] {
            let cl = ClusterConfig::new(2, placement).with_engine(EngineStrategy::Event);
            let route = RoutePolicy::RoundRobin;
            let reference =
                run_cluster(&cfg, &model, &trace, &cl, &sched, route, true).state_hash();

            // Drive half-way (into the drain for dp, mid-arrivals is
            // covered by the smaller step count on pp), snapshot, and
            // round-trip the snapshot through its serialized text.
            let mut first = Campaign::new(&cfg, &model, &trace, &cl, &sched, route, true, None);
            let budget = if placement == Placement::DataParallel { 14 } else { 6 };
            for _ in 0..budget {
                if !first.step(2) {
                    break;
                }
            }
            let snap = Json::parse(&first.snapshot_json().compact()).expect("snapshot parses");

            let mut resumed =
                Campaign::new(&cfg, &model, &trace, &cl, &sched, route, true, None);
            resumed.restore_json(&snap).expect("restore");
            let (r, _) = resumed.finish(None);
            assert_eq!(r.state_hash(), reference, "{placement}");

            // The interrupted original also finishes to the same hash.
            let (orig, _) = first.finish(None);
            assert_eq!(orig.state_hash(), reference, "{placement} original");
        }
    }

    #[test]
    fn streamed_campaign_snapshots_mid_stream_and_resumes() {
        let cfg = ArtemisConfig::default();
        let model = ModelZoo::transformer_base();
        let sc = Scenario::chat().with_sessions(10);
        let sched = SchedulerConfig { max_batch: 4, policy: Policy::Fifo };
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let route = RoutePolicy::RoundRobin;
        let trace = sc.generate(1);
        let reference = run_cluster(&cfg, &model, &trace, &cl, &sched, route, true).state_hash();

        // Streamed campaign, paused mid-arrivals (routed < total).
        let mut first =
            Campaign::new_streamed(&cfg, &model, sc.stream(1), &cl, &sched, route, true, None);
        for _ in 0..5 {
            assert!(first.step(2));
        }
        let (routed, total) = first.progress();
        assert!(0 < routed && routed < total, "pause must land mid-stream: {routed}/{total}");
        let snap = Json::parse(&first.snapshot_json().compact()).expect("snapshot parses");

        // The resumed campaign starts from a *wrong-seed* stream: the
        // snapshot's cursor carries the full RNG state, so restore
        // must land on the uninterrupted sequence regardless.
        let mut resumed =
            Campaign::new_streamed(&cfg, &model, sc.stream(99), &cl, &sched, route, true, None);
        resumed.restore_json(&snap).expect("restore");
        assert_eq!(resumed.progress().0, routed);
        let (r, _) = resumed.finish(None);
        assert_eq!(r.state_hash(), reference);

        // The interrupted original also finishes to the same hash.
        let (orig, _) = first.finish(None);
        assert_eq!(orig.state_hash(), reference);
    }

    #[test]
    fn stream_cursor_and_materialized_trace_do_not_mix() {
        let (cfg, model, trace, sched) = setup(4);
        let sc = Scenario::chat().with_sessions(4);
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let route = RoutePolicy::RoundRobin;
        let streamed =
            Campaign::new_streamed(&cfg, &model, sc.stream(1), &cl, &sched, route, true, None);
        let snap = streamed.snapshot_json();
        let mut ordered =
            Campaign::new(&cfg, &model, &trace, &cl, &sched, route, true, None);
        let err = ordered.restore_json(&snap).unwrap_err();
        assert!(err.contains("stream"), "{err}");

        let ordered_snap = ordered.snapshot_json();
        let mut streamed =
            Campaign::new_streamed(&cfg, &model, sc.stream(1), &cl, &sched, route, true, None);
        let err = streamed.restore_json(&ordered_snap).unwrap_err();
        assert!(err.contains("stream"), "{err}");
    }

    #[test]
    fn restore_rejects_shape_mismatches() {
        let (cfg, model, trace, sched) = setup(4);
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let donor = Campaign::new(
            &cfg,
            &model,
            &trace,
            &cl,
            &sched,
            RoutePolicy::RoundRobin,
            true,
            None,
        );
        let snap = donor.snapshot_json();
        // A 3-stack campaign cannot absorb a 2-stack snapshot.
        let cl3 = ClusterConfig::new(3, Placement::DataParallel);
        let mut other = Campaign::new(
            &cfg,
            &model,
            &trace,
            &cl3,
            &sched,
            RoutePolicy::RoundRobin,
            true,
            None,
        );
        let err = other.restore_json(&snap).unwrap_err();
        assert!(err.contains("replicas"), "{err}");
    }
}
