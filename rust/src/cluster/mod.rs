//! Multi-stack cluster scale-out: serve one generation trace across D
//! HBM stacks.
//!
//! Each stack runs the per-bank token dataflow internally (everything
//! `sim`/`dataflow` model); stacks compose in one of two placements
//! ([`Placement`](crate::config::Placement)):
//!
//! * **Data-parallel** (`dp`) — every stack is a full replica
//!   ([`ReplicaSim`]) owning whole sessions; an arriving session is
//!   routed to one replica by the [`Router`] policy (round-robin /
//!   least-loaded / KV-headroom) against per-stack KV capacity budgets.
//! * **Pipeline-parallel** (`pp`) — the stacks form one pipeline; each
//!   owns a contiguous layer range
//!   ([`stack_groups`](crate::dataflow::stack_groups)), activations hop
//!   stack-to-stack over the [`StackLink`](crate::dataflow::StackLink),
//!   and a steady-state decode tick advances by the bottleneck stage
//!   plus one hop (`sim::StackCoster`).
//!
//! All replicas share one memoized [`CostCache`]: the decomposed tick
//! costing makes structurally identical sub-workloads recur across
//! ticks, sessions and stacks, so the sharded cache removes most
//! `simulate` calls from the hot loop while staying bit-identical to
//! uncached costing (DESIGN.md §Cluster-scale-out).
//!
//! The driver interleaves the replicas on the shared simulated
//! timeline: before routing an arrival every replica is advanced to
//! the arrival time, so routing decisions see live load — and the
//! whole run stays deterministic for a fixed (trace, shape).  With
//! `ClusterConfig::threads != 1` the advances run on a scoped worker
//! pool (`parallel.rs`) — replicas are independent between routing
//! points, so every thread count produces bit-identical reports
//! (DESIGN.md §Performance-engineering).

mod campaign;
mod parallel;

pub use campaign::Campaign;

use crate::config::{ArtemisConfig, ClusterConfig, Placement, TransformerModel};
use crate::dataflow::{stack_groups, StackLink};
use crate::serve::{
    aggregate_report, is_arrival_sorted, Coster, KvTracker, Phase, PhaseProfile, PhaseTimer,
    Policy, ReplicaSim, RoutePolicy, Router, Scenario, SchedulerConfig, ServeGenReport,
    SessionSpec,
};
use crate::sim::{CacheStats, CostCache, SimOptions, StackCoster, StateHash};
use crate::telemetry::{build_trace, Trace, TraceConfig, TraceMeta};
use std::sync::Arc;

/// Outcome of one cluster run: per-stack reports plus the exact
/// aggregate (merged histograms, summed tokens/energy, max makespan).
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub stacks: u64,
    pub placement: Placement,
    pub route: RoutePolicy,
    /// Whether the memoized cost cache was enabled.
    pub cached: bool,
    /// Driver threads actually used (after auto-resolution).
    pub threads: usize,
    pub per_stack: Vec<ServeGenReport>,
    pub aggregate: ServeGenReport,
    /// Cost-cache lookup stats aggregated over *every* replica's
    /// coster (local dense tables + shared consults): the one accurate
    /// run-wide hit-rate line.  Deterministic for a fixed run shape,
    /// including across thread counts.
    pub cache: CacheStats,
    /// Per-replica lookup attribution.  Under a multi-threaded driver
    /// the *attribution* of a first-touch miss between two replicas
    /// racing on the same key is scheduling-dependent; only the
    /// aggregate above is deterministic.
    pub cache_per_stack: Vec<CacheStats>,
    /// Per-phase wall-time roll-up over every replica plus the driver's
    /// routing section (all zeros unless built with
    /// `--features profiling`).
    pub profile: PhaseProfile,
}

impl ClusterReport {
    /// Cluster-wide delivered generation throughput.
    pub fn tokens_per_s(&self) -> f64 {
        self.aggregate.tokens_per_s()
    }

    /// Deterministic digest of the whole run's simulated outcome: the
    /// aggregate report's hash plus every per-stack report's, in stack
    /// order.  Engine / thread-count / cache-on-off equivalence of a
    /// cluster run collapses to one `u64` comparison (the covered
    /// fields and exclusions are documented at
    /// [`ServeGenReport::state_hash`]).
    pub fn state_hash(&self) -> u64 {
        let mut h = StateHash::new();
        h.write_u64(self.aggregate.state_hash());
        h.write_usize(self.per_stack.len());
        for s in &self.per_stack {
            h.write_u64(s.state_hash());
        }
        h.finish()
    }
}

/// Serve `trace` on a D-stack cluster.
///
/// `cfg` describes one stack (weights are replicated per stack under
/// `dp`; split by layer range under `pp`).  Deterministic: same
/// (cfg, model, trace, cluster, sched, route) → same report, cache on
/// or off (`cached` only changes wall-clock, never a metric bit).
pub fn run_cluster(
    cfg: &ArtemisConfig,
    model: &TransformerModel,
    trace: &[SessionSpec],
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    route: RoutePolicy,
    cached: bool,
) -> ClusterReport {
    let cache = cached.then(CostCache::shared);
    run_cluster_inner(cfg, model, trace, cluster, sched, route, cache, cached, None).0
}

/// [`run_cluster`] against a caller-owned shared cost cache: the
/// design-search runner threads one cache through every candidate of a
/// sweep that shares a coster shape, so structurally identical tick
/// costs are simulated once per sweep instead of once per candidate.
/// Sound because the memoized layer sits below the fidelity overrides
/// (`cfg.fidelity` never reaches the coster) — and bit-identical to a
/// private cache, which is what `tests/search_properties.rs` pins.
pub fn run_cluster_with_cache(
    cfg: &ArtemisConfig,
    model: &TransformerModel,
    trace: &[SessionSpec],
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    route: RoutePolicy,
    cache: Arc<CostCache>,
) -> ClusterReport {
    run_cluster_inner(cfg, model, trace, cluster, sched, route, Some(cache), true, None).0
}

/// [`run_cluster`] with telemetry enabled on every replica: also
/// returns the run's structured trace, merged across replicas in
/// replica-index order (the same deterministic order the parallel
/// driver collects results in, so `--threads` never moves a trace
/// byte).  The report — and its state hash — is bit-identical to the
/// untraced run's.
#[allow(clippy::too_many_arguments)] // run_cluster's knobs + the trace pair
pub fn run_cluster_traced(
    cfg: &ArtemisConfig,
    model: &TransformerModel,
    trace: &[SessionSpec],
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    route: RoutePolicy,
    cached: bool,
    tc: &TraceConfig,
    meta: &TraceMeta,
) -> (ClusterReport, Trace) {
    let cache = cached.then(CostCache::shared);
    let tracing = Some((tc, meta));
    let (report, doc) =
        run_cluster_inner(cfg, model, trace, cluster, sched, route, cache, cached, tracing);
    (report, doc.expect("telemetry was enabled"))
}

/// Build the replica set for a cluster shape — every full replica per
/// stack under `dp`, one logical replica over the stack groups under
/// `pp` — with telemetry not yet enabled.  Shared by the one-shot
/// driver ([`run_cluster`]) and the incremental [`Campaign`] so both
/// execute the exact same construction sequence.  The shared cost
/// cache is created here; replicas hold their own handles, so the
/// local binding dropping on return is inert.
pub(crate) fn build_replicas<'a>(
    cfg: &'a ArtemisConfig,
    model: &'a TransformerModel,
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    cached: bool,
) -> Vec<ReplicaSim<'a>> {
    build_replicas_with(cfg, model, cluster, sched, cached.then(CostCache::shared))
}

/// [`build_replicas`] with an explicit (possibly caller-shared) cost
/// cache instead of a fresh per-run one; `None` runs uncached.
pub(crate) fn build_replicas_with<'a>(
    cfg: &'a ArtemisConfig,
    model: &'a TransformerModel,
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    cache: Option<Arc<CostCache>>,
) -> Vec<ReplicaSim<'a>> {
    let opts = SimOptions::artemis();
    let layers = model.layers as u64;

    let fidelity = crate::fidelity::ServeFidelity::for_model(&cfg.fidelity, model);
    match cluster.placement {
        Placement::DataParallel => (0..cluster.stacks)
            .map(|_| {
                let coster =
                    Coster::Stack(StackCoster::single(cfg, model, opts, cache.clone()));
                ReplicaSim::new(
                    model,
                    sched.clone(),
                    coster,
                    KvTracker::new(cfg, model),
                    layers,
                    fidelity.clone(),
                    cluster.engine,
                )
            })
            .collect(),
        Placement::PipelineParallel => {
            let groups = stack_groups(layers, cluster.stacks);
            let link = StackLink::new(&cluster.link);
            let coster = Coster::Stack(StackCoster::pipelined(
                cfg,
                model,
                opts,
                cache.clone(),
                &groups,
                link,
            ));
            // The binding stack owns the most layers: its weight share
            // and KV footprint gate admission for the whole group.
            let l_max = groups.iter().map(|g| g.len()).max().unwrap_or(layers).max(1);
            let kv = KvTracker::for_layer_share(cfg, model, l_max);
            vec![ReplicaSim::new(
                model,
                sched.clone(),
                coster,
                kv,
                l_max,
                fidelity.clone(),
                cluster.engine,
            )]
        }
    }
}

#[allow(clippy::too_many_arguments)] // internal: the union of both entry points
fn run_cluster_inner(
    cfg: &ArtemisConfig,
    model: &TransformerModel,
    trace: &[SessionSpec],
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    route: RoutePolicy,
    cache: Option<Arc<CostCache>>,
    cached: bool,
    tracing: Option<(&TraceConfig, &TraceMeta)>,
) -> (ClusterReport, Option<Trace>) {
    assert!(cluster.stacks > 0, "cluster needs at least one stack");
    let mut replicas = build_replicas_with(cfg, model, cluster, sched, cache);
    if let Some((tc, _)) = tracing {
        for r in replicas.iter_mut() {
            r.enable_telemetry(tc);
        }
    }

    // Generated traces are already `(arrival, id)`-sorted: borrow them
    // as-is and only clone-and-sort genuinely unordered input.
    let sorted;
    let order: &[SessionSpec] = if is_arrival_sorted(trace) {
        trace
    } else {
        sorted = {
            let mut v = trace.to_vec();
            v.sort_by(|a, b| a.arrival_ns.total_cmp(&b.arrival_ns).then(a.id.cmp(&b.id)));
            v
        };
        &sorted
    };
    let mut routing_profile = PhaseProfile::default();
    let threads = resolve_threads(cluster.threads, replicas.len());
    let replicas =
        drive_cluster(replicas, order.iter().copied(), route, threads, &mut routing_profile);
    assemble_report(
        replicas,
        model,
        cluster,
        sched,
        route,
        cached,
        threads,
        routing_profile,
        tracing,
    )
}

/// Interleave the replicas on the shared timeline: advance everyone to
/// each arrival, route it against live load, hand it over.  The serial
/// loop and the worker pool execute the same per-replica call sequence,
/// so both are bit-identical (tests/perf_properties).  Arrivals are
/// consumed one at a time — a lazy stream keeps cluster memory at
/// O(active sessions), independent of trace length.
fn drive_cluster<'a, I: Iterator<Item = SessionSpec>>(
    mut replicas: Vec<ReplicaSim<'a>>,
    arrivals: I,
    route: RoutePolicy,
    threads: usize,
    routing_profile: &mut PhaseProfile,
) -> Vec<ReplicaSim<'a>> {
    let mut router = Router::new(route);
    if threads <= 1 {
        for spec in arrivals {
            for r in replicas.iter_mut() {
                r.advance_to(spec.arrival_ns);
            }
            let timer = PhaseTimer::start();
            let loads: Vec<_> = replicas.iter().enumerate().map(|(i, r)| r.load(i)).collect();
            let pick = router.route(&loads);
            timer.stop(routing_profile, Phase::Routing);
            replicas[pick].push(spec);
        }
        for r in replicas.iter_mut() {
            r.run_to_completion();
        }
        replicas
    } else {
        parallel::drive_parallel(replicas, arrivals, &mut router, threads, routing_profile)
    }
}

/// [`run_cluster`] over a lazy arrival stream (nondecreasing
/// `(arrival_ns, id)` order required — [`Scenario::stream`] satisfies
/// it by construction).  Arrivals are pulled one at a time, so cluster
/// memory stays O(active sessions + bounded accumulators) regardless of
/// trace length.  Bit-identical to materializing the same sequence and
/// calling [`run_cluster`].
pub fn run_cluster_stream<I: Iterator<Item = SessionSpec>>(
    cfg: &ArtemisConfig,
    model: &TransformerModel,
    arrivals: I,
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    route: RoutePolicy,
    cached: bool,
) -> ClusterReport {
    assert!(cluster.stacks > 0, "cluster needs at least one stack");
    let replicas = build_replicas(cfg, model, cluster, sched, cached);
    let mut routing_profile = PhaseProfile::default();
    let threads = resolve_threads(cluster.threads, replicas.len());
    let replicas = drive_cluster(replicas, arrivals, route, threads, &mut routing_profile);
    assemble_report(replicas, model, cluster, sched, route, cached, threads, routing_profile, None)
        .0
}

/// Assemble the finished replicas into the [`ClusterReport`] (labels,
/// per-stack + aggregate reports, cache stats, profile roll-up) and
/// drain the telemetry trace.  Shared by [`run_cluster`] and
/// [`Campaign::finish`], so the incremental driver's output is
/// byte-identical to the one-shot driver's.
#[allow(clippy::too_many_arguments)] // internal: the report's full provenance
pub(crate) fn assemble_report(
    mut replicas: Vec<ReplicaSim<'_>>,
    model: &TransformerModel,
    cluster: &ClusterConfig,
    sched: &SchedulerConfig,
    route: RoutePolicy,
    cached: bool,
    threads: usize,
    routing_profile: PhaseProfile,
    tracing: Option<(&TraceConfig, &TraceMeta)>,
) -> (ClusterReport, Option<Trace>) {
    let label = format!(
        "{} {} b{} {}",
        cluster.label(),
        route,
        sched.max_batch,
        if cached { "cache" } else { "nocache" }
    );
    let per_stack: Vec<ServeGenReport> = replicas
        .iter()
        .enumerate()
        .map(|(i, r)| r.report(format!("stack{i}({label})")))
        .collect();
    let aggregate = aggregate_report(&replicas, format!("cluster({label})"), model);
    // The run-wide hit-rate line aggregates every replica's coster
    // counters (local dense tables *and* shared consults) — the
    // per-replica/reset-between-runs stats bug the PR 5 satellite
    // fixed.  The shared handle's own stats only cover shared
    // consults, so they are not the number to report.
    let cache_per_stack: Vec<CacheStats> = replicas.iter().map(|r| r.cache_stats()).collect();
    let cache_stats =
        cache_per_stack.iter().fold(CacheStats::default(), |acc, &s| acc.merged(s));
    // Roll per-phase wall time up across replicas; the driver's routing
    // section (which ticks no replica) rides along with ticks = 0.
    let mut profile = routing_profile;
    for r in &replicas {
        profile.merge(r.profile());
    }
    // Drain telemetry in replica-index order — the merge order, like
    // the report order, is independent of the driver thread count.
    let doc = tracing.map(|(tc, meta)| {
        let parts = replicas
            .iter_mut()
            .enumerate()
            .map(|(i, r)| r.drain_telemetry(i).expect("telemetry was enabled"))
            .collect();
        let mut t = build_trace(parts, tc, meta);
        t.attach_profile(&profile);
        t
    });

    let report = ClusterReport {
        stacks: cluster.stacks,
        placement: cluster.placement,
        route,
        cached,
        threads,
        per_stack,
        aggregate,
        cache: cache_stats,
        cache_per_stack,
        profile,
    };
    (report, doc)
}

/// Resolve the driver-thread request: `0` = one thread per replica,
/// capped by the machine's available parallelism; always in
/// `[1, replicas]`.
fn resolve_threads(requested: usize, replicas: usize) -> usize {
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 { auto } else { requested };
    t.clamp(1, replicas.max(1))
}

/// Run one named-scenario cluster point: seeded trace, FIFO admission,
/// least-loaded routing — the shape the `cluster-scale` report and the
/// `bench-serve` suite sweep.  `threads = 0` auto-sizes the driver
/// pool; the thread count never moves a reported bit.
pub fn run_scenario_cluster(
    cfg: &ArtemisConfig,
    scenario: &Scenario,
    stacks: u64,
    placement: Placement,
    seed: u64,
    cached: bool,
    threads: usize,
) -> ClusterReport {
    let sched = SchedulerConfig::for_scenario(scenario, Policy::Fifo);
    let cluster = ClusterConfig::new(stacks, placement).with_threads(threads);
    run_cluster_stream(
        cfg,
        &scenario.model,
        scenario.stream(seed),
        &cluster,
        &sched,
        RoutePolicy::LeastLoaded,
        cached,
    )
}

/// Convenience: run the chat-trace scaling point used by the
/// `cluster-scale` report and the CI serve benchmark.
pub fn run_chat_cluster(
    cfg: &ArtemisConfig,
    stacks: u64,
    placement: Placement,
    seed: u64,
    sessions: usize,
    cached: bool,
) -> ClusterReport {
    let sc = Scenario::chat().with_sessions(sessions);
    run_scenario_cluster(cfg, &sc, stacks, placement, seed, cached, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelZoo;
    use crate::serve::{Policy, Scenario};

    fn fast_trace(n: usize) -> (ArtemisConfig, TransformerModel, Vec<SessionSpec>) {
        let cfg = ArtemisConfig::default();
        let model = ModelZoo::transformer_base(); // 2 layers: fast sim
        let sc = Scenario::chat().with_sessions(n);
        (cfg, model, sc.generate(1))
    }

    fn sched(batch: usize) -> SchedulerConfig {
        SchedulerConfig { max_batch: batch, policy: Policy::Fifo }
    }

    #[test]
    fn thread_resolution_is_bounded() {
        assert_eq!(resolve_threads(1, 4), 1, "explicit serial stays serial");
        assert_eq!(resolve_threads(8, 4), 4, "never more workers than replicas");
        assert_eq!(resolve_threads(3, 1), 1, "pp groups are one logical replica");
        assert_eq!(resolve_threads(5, 0), 1, "degenerate empty cluster");
        let auto = resolve_threads(0, 4);
        assert!((1..=4).contains(&auto), "auto out of range: {auto}");
    }

    #[test]
    fn reports_carry_resolved_threads_and_per_stack_stats() {
        let (cfg, model, trace) = fast_trace(8);
        let cl = ClusterConfig::new(2, Placement::DataParallel).with_threads(2);
        let r = run_cluster(&cfg, &model, &trace, &cl, &sched(4), RoutePolicy::RoundRobin, true);
        assert_eq!(r.threads, 2);
        assert_eq!(r.cache_per_stack.len(), 2);
        let summed = r
            .cache_per_stack
            .iter()
            .fold(CacheStats::default(), |acc, &s| acc.merged(s));
        assert_eq!(summed, r.cache);
    }

    #[test]
    fn dp_serves_every_session_exactly_once() {
        let (cfg, model, trace) = fast_trace(12);
        let cl = ClusterConfig::new(3, Placement::DataParallel);
        let r = run_cluster(&cfg, &model, &trace, &cl, &sched(4), RoutePolicy::RoundRobin, true);
        assert_eq!(r.per_stack.len(), 3);
        assert_eq!(r.aggregate.sessions, 12);
        assert_eq!(r.aggregate.rejected, 0);
        let want: u64 = trace.iter().map(|s| s.gen).sum();
        assert_eq!(r.aggregate.total_tokens, want);
        // Every session id appears exactly once across the stacks.
        let mut ids: Vec<u64> = r
            .per_stack
            .iter()
            .flat_map(|s| s.session_reports.iter().map(|x| x.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<u64>>());
        // And the aggregate lists them in id order.
        let agg_ids: Vec<u64> = r.aggregate.session_reports.iter().map(|s| s.id).collect();
        assert_eq!(agg_ids, (0..12).collect::<Vec<u64>>());
    }

    #[test]
    fn more_stacks_raise_aggregate_throughput() {
        let (cfg, model, trace) = fast_trace(16);
        let one = ClusterConfig::new(1, Placement::DataParallel);
        let four = ClusterConfig::new(4, Placement::DataParallel);
        let r1 = run_cluster(&cfg, &model, &trace, &one, &sched(4), RoutePolicy::LeastLoaded, true);
        let r4 =
            run_cluster(&cfg, &model, &trace, &four, &sched(4), RoutePolicy::LeastLoaded, true);
        assert_eq!(r1.aggregate.total_tokens, r4.aggregate.total_tokens);
        assert!(
            r4.tokens_per_s() > r1.tokens_per_s(),
            "4 stacks {} tok/s vs 1 stack {} tok/s",
            r4.tokens_per_s(),
            r1.tokens_per_s()
        );
        assert!(r4.aggregate.makespan_ns < r1.aggregate.makespan_ns);
    }

    #[test]
    fn cache_on_off_is_bit_identical_with_high_hit_rate() {
        let (cfg, model, trace) = fast_trace(24);
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let hot = run_cluster(&cfg, &model, &trace, &cl, &sched(8), RoutePolicy::LeastLoaded, true);
        let cold =
            run_cluster(&cfg, &model, &trace, &cl, &sched(8), RoutePolicy::LeastLoaded, false);
        // Memoization must not move a single bit of any metric.
        let (h, c) = (&hot.aggregate, &cold.aggregate);
        assert_eq!(h.makespan_ns.to_bits(), c.makespan_ns.to_bits());
        assert_eq!(h.sim_energy_pj.to_bits(), c.sim_energy_pj.to_bits());
        assert_eq!(h.per_token.mean.to_bits(), c.per_token.mean.to_bits());
        assert_eq!(h.ttft.p99.to_bits(), c.ttft.p99.to_bits());
        assert_eq!(h.total_tokens, c.total_tokens);
        assert_eq!(h.ticks, c.ticks);
        // The cache actually worked (and the uncached run never looked).
        assert!(hot.cache.hit_rate() > 0.8, "hit rate {}", hot.cache.hit_rate());
        assert_eq!(cold.cache, CacheStats::default());
    }

    #[test]
    fn caller_shared_cache_is_bit_identical_and_warm() {
        // The design-search runner reuses one cache across candidates;
        // a pre-warmed shared cache must not move a reported bit, and
        // the second run over the same shape must hit almost always.
        let (cfg, model, trace) = fast_trace(10);
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let private =
            run_cluster(&cfg, &model, &trace, &cl, &sched(4), RoutePolicy::RoundRobin, true);
        let cache = CostCache::shared();
        let first = run_cluster_with_cache(
            &cfg, &model, &trace, &cl, &sched(4), RoutePolicy::RoundRobin, cache.clone(),
        );
        let warm = run_cluster_with_cache(
            &cfg, &model, &trace, &cl, &sched(4), RoutePolicy::RoundRobin, cache,
        );
        assert_eq!(private.state_hash(), first.state_hash());
        assert_eq!(first.state_hash(), warm.state_hash());
        assert!(warm.cache.hit_rate() > first.cache.hit_rate(), "warm reuse must raise hits");
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let (cfg, model, trace) = fast_trace(10);
        let cl = ClusterConfig::new(4, Placement::DataParallel);
        let routes =
            [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::KvHeadroom];
        for route in routes {
            let a = run_cluster(&cfg, &model, &trace, &cl, &sched(4), route, true);
            let b = run_cluster(&cfg, &model, &trace, &cl, &sched(4), route, true);
            assert_eq!(a.aggregate.makespan_ns.to_bits(), b.aggregate.makespan_ns.to_bits());
            assert_eq!(a.aggregate.total_tokens, b.aggregate.total_tokens);
            assert_eq!(a.aggregate.rejected, b.aggregate.rejected);
            // All policies serve the full trace on an uncontended cluster.
            assert_eq!(a.aggregate.rejected, 0);
        }
    }

    #[test]
    fn pp_group_beats_one_stack_on_throughput() {
        let (cfg, model, trace) = fast_trace(12);
        let one = ClusterConfig::new(1, Placement::DataParallel);
        let pp2 = ClusterConfig::new(2, Placement::PipelineParallel);
        let r1 = run_cluster(&cfg, &model, &trace, &one, &sched(4), RoutePolicy::LeastLoaded, true);
        let rp =
            run_cluster(&cfg, &model, &trace, &pp2, &sched(4), RoutePolicy::LeastLoaded, true);
        assert_eq!(rp.per_stack.len(), 1, "pp group is one logical replica");
        assert_eq!(rp.aggregate.total_tokens, r1.aggregate.total_tokens);
        // Halving the per-stage layer count shrinks the bottleneck
        // tick below the whole-stack tick (hop included).
        assert!(
            rp.tokens_per_s() > r1.tokens_per_s(),
            "pp x2 {} tok/s vs single {} tok/s",
            rp.tokens_per_s(),
            r1.tokens_per_s()
        );
    }

    #[test]
    fn pp_kv_budget_grows_with_freed_weight_room() {
        // A pp stage stores only its layer share of weights and KV: the
        // binding stack's budget must be >= the whole-model budget.
        let (cfg, model, trace) = fast_trace(6);
        let pp = ClusterConfig::new(2, Placement::PipelineParallel);
        let r = run_cluster(&cfg, &model, &trace, &pp, &sched(4), RoutePolicy::LeastLoaded, true);
        let full = KvTracker::new(&cfg, &model);
        assert!(r.aggregate.kv_budget_per_bank >= full.budget_per_bank());
        assert!(r.aggregate.peak_kv_per_bank <= r.aggregate.kv_budget_per_bank);
    }

    #[test]
    fn empty_trace_yields_empty_report() {
        let (cfg, model, _) = fast_trace(0);
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let r = run_cluster(&cfg, &model, &[], &cl, &sched(4), RoutePolicy::LeastLoaded, true);
        assert_eq!(r.aggregate.sessions, 0);
        assert_eq!(r.aggregate.total_tokens, 0);
        assert_eq!(r.aggregate.makespan_ns, 0.0);
        assert_eq!(r.cache.lookups(), 0);
    }

    #[test]
    fn engine_strategy_is_a_pure_wall_clock_knob() {
        use crate::config::EngineStrategy;
        let (cfg, model, trace) = fast_trace(10);
        for placement in [Placement::DataParallel, Placement::PipelineParallel] {
            let base = ClusterConfig::new(2, placement);
            let tick =
                run_cluster(&cfg, &model, &trace, &base, &sched(4), RoutePolicy::LeastLoaded, true);
            let event = run_cluster(
                &cfg,
                &model,
                &trace,
                &base.with_engine(EngineStrategy::Event),
                &sched(4),
                RoutePolicy::LeastLoaded,
                true,
            );
            assert_eq!(tick.state_hash(), event.state_hash(), "{placement}");
            // The hash is the digest of the full reports, so spot-check
            // that it is standing in for real field equality.
            assert_eq!(
                tick.aggregate.makespan_ns.to_bits(),
                event.aggregate.makespan_ns.to_bits()
            );
            assert_eq!(tick.aggregate.ticks, event.aggregate.ticks);
        }
    }

    #[test]
    fn streamed_cluster_matches_materialized_bit_for_bit() {
        // The lazy TraceStream path must reproduce the materialized
        // path's hash on both placements and both driver modes.
        let cfg = ArtemisConfig::default();
        let model = ModelZoo::transformer_base();
        let sc = Scenario::chat().with_sessions(12);
        let trace = sc.generate(1);
        for placement in [Placement::DataParallel, Placement::PipelineParallel] {
            for threads in [1, 2] {
                let cl = ClusterConfig::new(2, placement).with_threads(threads);
                let eager = run_cluster(
                    &cfg,
                    &model,
                    &trace,
                    &cl,
                    &sched(4),
                    RoutePolicy::LeastLoaded,
                    true,
                );
                let lazy = run_cluster_stream(
                    &cfg,
                    &model,
                    sc.stream(1),
                    &cl,
                    &sched(4),
                    RoutePolicy::LeastLoaded,
                    true,
                );
                assert_eq!(
                    eager.state_hash(),
                    lazy.state_hash(),
                    "{placement} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn kv_headroom_routing_respects_budgets_under_pressure() {
        // Tiny banks + summarize-length sessions: KV pressure is real;
        // every stack must stay within budget and every session must be
        // served or cleanly rejected.
        let mut cfg = ArtemisConfig::default();
        cfg.hbm.subarrays_per_bank = 16;
        let model = ModelZoo::transformer_base();
        let sc = Scenario::summarize().with_sessions(10);
        let trace = sc.generate(3);
        let cl = ClusterConfig::new(2, Placement::DataParallel);
        let r = run_cluster(&cfg, &model, &trace, &cl, &sched(8), RoutePolicy::KvHeadroom, true);
        for s in &r.per_stack {
            assert!(s.peak_kv_per_bank <= s.kv_budget_per_bank);
        }
        for s in &r.aggregate.session_reports {
            assert!(s.rejected || s.generated == s.gen);
        }
    }
}
