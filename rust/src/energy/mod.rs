//! Energy and power accounting (Table I energy rows + Table III circuit
//! energies) with the 60 W module power budget (Section IV preamble).

mod account;

pub use account::{EnergyAccount, EnergyBreakdown};

use crate::config::{ArtemisConfig, FidelityParams};

/// Energy scale of running the SC substrate at MAC-weighted mean
/// stream length `mean_len` relative to the 128-bit reference.
///
/// The activation, MOMCAP-charge and conversion energies all scale
/// with the stream bit count (each bit position is one S/A toggle and
/// one charge step); the NSC/movement/static energies do not.
/// `beta_energy` is the scaling share — at `mean_len == 128` the factor
/// is exactly 1.0 (see `config::FidelityParams`).
pub fn sc_stream_energy_factor(p: &FidelityParams, mean_len: f64) -> f64 {
    (1.0 - p.beta_energy) + p.beta_energy * mean_len / 128.0
}

/// Derived power-budget throttle.
///
/// Activating every subarray of every bank concurrently would blow far
/// past the 60 W HBM budget, so (like real DRAM's tFAW) the scheduler
/// bounds concurrent activation.  We derive the sustainable MAC-step
/// concurrency from the budget: the fraction of nominal peak concurrency
/// the module can sustain thermally.  See DESIGN.md §Modeling-decisions.
#[derive(Debug, Clone, Copy)]
pub struct PowerThrottle {
    /// Peak concurrent MAC-step power if everything fired at once, W.
    pub peak_w: f64,
    /// Fraction of peak concurrency that fits the budget (<= 1).
    pub duty: f64,
}

/// Energy drawn by one 64-MAC subarray step: 2 AAPs (4 activations) plus
/// the MOMCAP charge transfer (circuit-level, small).
pub fn subarray_step_energy_pj(cfg: &ArtemisConfig) -> f64 {
    let e = &cfg.hbm.energy;
    // 2 MOCs x 2 activations each.
    4.0 * e.e_act_pj
}

/// Compute the power throttle for a configuration.  The dynamic budget
/// is what remains of the module budget after static power.
pub fn power_throttle(cfg: &ArtemisConfig) -> PowerThrottle {
    let step_e_pj = subarray_step_energy_pj(cfg);
    let step_ns = cfg.hbm.timing.mac_step_ns;
    let concurrent_subarrays =
        (cfg.hbm.banks_total() * cfg.hbm.active_subarrays_per_bank()) as f64;
    let peak_w = concurrent_subarrays * step_e_pj * 1e-12 / (step_ns * 1e-9);
    let dynamic_budget = (cfg.power_budget_w - cfg.static_power_w).max(1.0);
    let duty = (dynamic_budget / peak_w).min(1.0);
    PowerThrottle { peak_w, duty }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throttle_binds_at_default_config() {
        // With Table I energies the unthrottled peak is way above 60 W —
        // the budget must bind.
        let t = power_throttle(&ArtemisConfig::default());
        assert!(t.peak_w > 60.0);
        assert!(t.duty < 1.0);
        assert!(t.duty > 0.0);
    }

    #[test]
    fn bigger_budget_raises_duty() {
        let mut cfg = ArtemisConfig::default();
        let d1 = power_throttle(&cfg).duty;
        cfg.power_budget_w *= 2.0;
        let d2 = power_throttle(&cfg).duty;
        assert!(d2 > d1);
    }

    #[test]
    fn step_energy_is_4_activations() {
        let cfg = ArtemisConfig::default();
        assert!((subarray_step_energy_pj(&cfg) - 4.0 * 909.0).abs() < 1e-9);
    }
}
