//! The energy ledger every simulation run fills in.

use crate::config::ArtemisConfig;
use crate::dram::CommandCounter;

/// Itemized energy breakdown, pJ.
#[derive(Debug, Clone, Default)]
pub struct EnergyBreakdown {
    /// DRAM row activations (MAC passes + copies).
    pub activation_pj: f64,
    /// Intra-bank datapath (row buffer -> GSA).
    pub pre_gsa_pj: f64,
    /// GSA -> DRAM I/O (inter-bank movement on the shared bus).
    pub post_gsa_pj: f64,
    /// Off-module I/O (inputs in, results out).
    pub io_pj: f64,
    /// NSC circuit energy (adders, LUTs, comparators, B_to_TCU, latches).
    pub nsc_pj: f64,
    /// S_to_B / A_to_B conversion circuit energy.
    pub conversion_pj: f64,
    /// MOMCAP charge/discharge energy.
    pub momcap_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.activation_pj
            + self.pre_gsa_pj
            + self.post_gsa_pj
            + self.io_pj
            + self.nsc_pj
            + self.conversion_pj
            + self.momcap_pj
    }

    pub fn total_mj(&self) -> f64 {
        self.total_pj() * 1e-9
    }

    pub fn add(&mut self, other: &Self) {
        self.activation_pj += other.activation_pj;
        self.pre_gsa_pj += other.pre_gsa_pj;
        self.post_gsa_pj += other.post_gsa_pj;
        self.io_pj += other.io_pj;
        self.nsc_pj += other.nsc_pj;
        self.conversion_pj += other.conversion_pj;
        self.momcap_pj += other.momcap_pj;
    }
}

/// Running energy account bound to a configuration.
///
/// Borrows its configuration: accounts are created once per
/// [`simulate`](crate::sim::simulate) call, which sits on the serving
/// hot path — cloning the whole `ArtemisConfig` per call was one of the
/// per-tick allocations the cost profile flagged
/// (DESIGN.md §Performance-engineering).
#[derive(Debug, Clone)]
pub struct EnergyAccount<'a> {
    cfg: &'a ArtemisConfig,
    pub breakdown: EnergyBreakdown,
}

impl<'a> EnergyAccount<'a> {
    pub fn new(cfg: &'a ArtemisConfig) -> Self {
        Self { cfg, breakdown: EnergyBreakdown::default() }
    }

    /// Charge a batch of DRAM commands.
    pub fn charge_commands(&mut self, cmds: &CommandCounter) {
        let e = &self.cfg.hbm.energy;
        self.breakdown.activation_pj += cmds.activation_energy_pj(e);
        // Each MOMCAP charge step moves one row of bit-line charge:
        // CV^2-scale, tiny; modeled via the latch circuit power class.
        self.breakdown.momcap_pj +=
            cmds.momcap_charges as f64 * 0.05; // ~0.05 pJ per K1 toggle
        self.breakdown.conversion_pj += cmds.a_to_bs as f64
            * self.cfg.circuits.s_to_b.energy_pj();
    }

    /// Charge intra-bank data movement of `bits` (row buffer -> GSA).
    pub fn charge_pre_gsa(&mut self, bits: u64) {
        self.breakdown.pre_gsa_pj +=
            bits as f64 * self.cfg.hbm.energy.e_pre_gsa_pj_per_bit;
    }

    /// Charge inter-bank movement of `bits` (GSA -> I/O path).
    pub fn charge_post_gsa(&mut self, bits: u64) {
        self.breakdown.post_gsa_pj +=
            bits as f64 * self.cfg.hbm.energy.e_post_gsa_pj_per_bit;
    }

    /// Charge off-module I/O of `bits`.
    pub fn charge_io(&mut self, bits: u64) {
        self.breakdown.io_pj += bits as f64 * self.cfg.hbm.energy.e_io_pj_per_bit;
    }

    /// Charge `n` NSC operations of one circuit class.
    pub fn charge_nsc_ops(&mut self, circuit_energy_pj: f64, n: u64) {
        self.breakdown.nsc_pj += circuit_energy_pj * n as f64;
    }

    /// Average power over a run of `total_ns`, W.
    pub fn average_power_w(&self, total_ns: f64) -> f64 {
        if total_ns <= 0.0 {
            return 0.0;
        }
        self.breakdown.total_pj() * 1e-12 / (total_ns * 1e-9)
    }

    /// True if the run respected the module budget.
    pub fn within_budget(&self, total_ns: f64) -> bool {
        self.average_power_w(total_ns) <= self.cfg.power_budget_w * 1.001
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::DramCommand;

    #[test]
    fn totals_add_up() {
        let mut b = EnergyBreakdown::default();
        b.activation_pj = 1.0;
        b.io_pj = 2.0;
        b.nsc_pj = 3.0;
        assert_eq!(b.total_pj(), 6.0);
    }

    #[test]
    fn commands_charge_activation() {
        let cfg = ArtemisConfig::default();
        let mut acc = EnergyAccount::new(&cfg);
        let mut cmds = CommandCounter::new();
        cmds.record(DramCommand::Aap);
        acc.charge_commands(&cmds);
        assert!((acc.breakdown.activation_pj - 2.0 * 909.0).abs() < 1e-9);
    }

    #[test]
    fn datapath_charges_per_bit() {
        let cfg = ArtemisConfig::default();
        let mut acc = EnergyAccount::new(&cfg);
        acc.charge_pre_gsa(1000);
        acc.charge_post_gsa(1000);
        acc.charge_io(1000);
        assert!((acc.breakdown.pre_gsa_pj - 1510.0).abs() < 1e-9);
        assert!((acc.breakdown.post_gsa_pj - 1170.0).abs() < 1e-9);
        assert!((acc.breakdown.io_pj - 800.0).abs() < 1e-9);
    }

    #[test]
    fn average_power() {
        let cfg = ArtemisConfig::default();
        let mut acc = EnergyAccount::new(&cfg);
        acc.charge_io(1_000_000); // 0.8 uJ
        // over 1 ms -> 0.8 mW
        let p = acc.average_power_w(1e6);
        assert!((p - 8e-4).abs() < 1e-9, "p={p}");
        assert!(acc.within_budget(1e6));
    }

    #[test]
    fn breakdown_merge() {
        let mut a = EnergyBreakdown { activation_pj: 1.0, ..Default::default() };
        let b = EnergyBreakdown { activation_pj: 2.0, io_pj: 5.0, ..Default::default() };
        a.add(&b);
        assert_eq!(a.activation_pj, 3.0);
        assert_eq!(a.io_pj, 5.0);
    }
}
