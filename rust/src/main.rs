//! ARTEMIS CLI — the leader entrypoint.
//!
//! Subcommands regenerate every paper table/figure, run ad-hoc
//! simulations, and drive the serving demo.  Arg parsing is hand-rolled
//! (the offline build has no clap); `artemis help` lists everything.

use anyhow::{anyhow, Result};
use artemis::cluster::{run_cluster, run_cluster_stream, run_cluster_traced, run_scenario_cluster};
use artemis::config::{ArtemisConfig, ClusterConfig, EngineStrategy, Placement};
use artemis::coordinator::{evaluate_variants, Coordinator, InferenceRequest};
use artemis::daemon::run_daemon;
use artemis::dataflow::{Dataflow, Pipelining};
use artemis::report;
use artemis::runtime::ArtifactRegistry;
use artemis::search::{run_search, RunOptions, SearchSpec};
use artemis::serve::{
    meta_for, run_continuous_stream, run_continuous_traced, run_static_stream, PhaseProfile,
    Policy, RoutePolicy, Scenario, SchedulerConfig, ServeSpec,
};
use artemis::sim::SimOptions;
use artemis::telemetry::{
    build_trace, parse_trace, FileSink, NullSink, Trace, TraceConfig, TraceMeta, SCHEMA_VERSION,
};
use artemis::util::json::Json;
use artemis::util::XorShift64;

const HELP: &str = "\
artemis — mixed analog-stochastic in-DRAM accelerator (paper reproduction)

USAGE: artemis <command> [options]

Experiment commands (regenerate paper tables/figures):
  fig2      component-wise time on traditional PIM (DRISA)
  fig7      MOMCAP charge staircases across capacitances
  fig8      dataflow/pipelining sensitivity (speedup + energy)
  fig9      speedup vs CPU/GPU/TPU/FPGA/TransPIM/ReBERT/HAIMA
  fig10     energy comparison (normalized to CPU)
  fig11     power efficiency (GOPS/W)
  fig12     scalability: sequence length x HBM stacks
  tab3      per-subarray hardware overheads
  tab4      accuracy FP32 vs Q8 vs Q8+SC (reference backend, or
            artifacts/ + --features pjrt for the trained models)
  tab5      per-component calibration accuracy (measured)
  micro     headline micro numbers (34ns multiply, 64 MACs/48ns, ...)
  all       run every experiment above, print everything

Extension studies (beyond the paper's evaluation):
  decode    autoregressive generation: prefill + per-token decode
  noise     analog charge-noise sensitivity sweep
  ablation  deterministic (TCU) vs conventional LFSR stochastic multiply
  capacity  per-bank storage demand vs capacity, mapping rounds
  fidelity-sweep
            stream-length x analog-noise Pareto table: per-product and
            logit error (analytic SC model), estimated task accuracy,
            serving time/energy factors; plus the QoS serving comparison
  csv       write every table/figure as CSV into --outdir (default results/)

Other commands:
  simulate --model <name> [--dataflow token|layer] [--no-pipeline]
           [--stacks N] [--config file.json]
           detailed simulation report for one model
  serve    [--requests N] [--variant fp32|q8|q8sc]
           batched serving demo through the functional runtime
  serve-gen [--scenario chat|summarize|burst|long_itl] [--seed N]
           [--sessions N] [--policy fifo|spf] [--batch B] [--model name]
           [--qos gold|silver|bronze|mix] [--engine tick|event]
           [--stacks D] [--placement dp|pp] [--route rr|ll|kv]
           [--no-cost-cache] [--trace FILE] [--slo SPEC]
           [--trace-window MS] [--spec FILE]
           continuous-batching generation server on the simulated clock:
           TTFT + per-token p50/p95/p99 (simulated ns), tokens/s,
           estimated-accuracy percentiles, and the comparison against
           the static pad-and-drop batcher.  --qos serves every session
           at one fidelity tier (or a deterministic per-session mix):
           lower tiers run shorter SC streams — faster and cheaper per
           tick, lower estimated accuracy.  With --stacks D the trace is
           served by a D-stack cluster (dp = data-parallel replicas with
           session routing, pp = pipeline-parallel stack groups) through
           the memoized cost cache; per-stack and aggregate metrics plus
           the aggregated cache hit rate print.  --threads N picks the
           parallel driver's thread count (0 = auto, 1 = serial);
           every thread count reports bit-identical numbers.
           --engine picks the clock-advance strategy (tick = reference
           per-arrival loop, event = next-event heap with scan
           skipping); both report bit-identical numbers, attested by
           the printed state-hash line (one u64 over the whole run).
           --trace FILE streams the run's structured telemetry as
           versioned JSONL (session spans, windowed snapshots, per-tier
           SLO verdicts) — byte-identical across engines, thread
           counts, and cache modes, and the report's state hash never
           moves.  --slo sets per-tier p99 targets ('default' or e.g.
           'gold:ttft=100ms,itl=10ms;bronze:ttft=2s'); --trace-window
           sets the snapshot window in simulated ms (default 100).
           --spec FILE loads a serialized ServeSpec JSON document (the
           same schema the serve daemon accepts) as the base request;
           explicit flags layer over its fields
  serve-daemon [--listen ADDR]
           long-running serving daemon: line-delimited JSON over TCP
           (submit / status / snapshot / restore / resume /
           trace-window / reload-config / shutdown).  submit takes the
           same ServeSpec JSON as serve-gen --spec and drives the run
           incrementally on a worker thread; snapshot serializes the
           mid-run campaign state to a versioned document, and restore
           resumes it — finishing on the same state-hash line an
           uninterrupted run prints.  Default ADDR 127.0.0.1:0 (the
           bound address is announced on stdout)
  trace-report <trace.jsonl> [--top K]
           replay a --trace file into human-readable tables: run
           summary, per-tier SLO verdicts, top-K worst sessions,
           highest-burn windows, energy attribution by tier and phase
  cluster-scale
           scaling study: aggregate tokens/s and p99 latency for the
           chat trace on D = 1/2/4/8 stacks, both placements
  bench-serve [--out FILE] [--reps N] [--threads N]
           seeded serve-gen wall-clock suite (CI perf gate): every
           scenario (chat/summarize/burst) x placement (dp/pp) x cost
           cache (on/off) on 4 stacks, plus the idle-heavy long_itl
           point under both engines (tick vs event; state hashes are
           asserted equal); writes one consolidated JSON ({suite,
           threads, benches: [{bench, wall_ms, sim_tokens_per_s}]})
           to FILE.  Built with --features profiling it also embeds
           the per-phase ns/tick profile of the long_itl event run.
           Also re-times the long_itl event point with telemetry
           enabled into a null sink and records the overhead ratio
           under a top-level \"telemetry\" field, and stamps the
           process-lifetime peak RSS as a top-level \"peak_rss_bytes\"
  bench-scale [--sessions CSV] [--scenario NAME] [--seed N]
           [--out FILE] [--max-rss-mb N]
           streaming-core scale lane: serve --scenario (default chat)
           at each ascending session count in CSV (default
           10000,100000) through both engines via the lazy arrival
           stream and the O(active) slab store, asserting tick/event
           state-hash equality at every point.  Records wall-clock,
           sessions per wall-second, and peak RSS (VmHWM) per point
           into FILE (default BENCH_scale.json).  Fails if adjacent
           points >= 10x apart in sessions grow peak RSS by >= 3x
           (the sub-linear-memory gate CI runs at 1e5, advisory at
           1e6), or if --max-rss-mb is given and exceeded
  design-search [--stream-lens CSV] [--sigmas CSV] [--stacks CSV]
           [--placements CSV] [--hops CSV] [--qos CSV]
           [--sampler grid|random|halving] [--samples N] [--rungs R]
           [--sampler-seed N] [--shards K] [--out DIR] [--threads N]
           [--max-shards N] [--search FILE] [--no-cost-cache]
           [--scenario NAME] [--seed N] [--sessions N] [--model NAME]
           [--batch B] [--policy fifo|spf] [--engine tick|event]
           [--route rr|ll|kv] [--bench-out FILE]
           resumable design-space autotuner: sweeps the cross product
           of gold-tier SC stream length x analog noise x cluster
           stacks x placement x link hop latency x QoS mix, serves
           every candidate through the cluster driver, and prints the
           exact Pareto front over estimated accuracy x tokens/s x
           mJ/token (plus a deterministic front-hash digest).
           --sampler random draws a seeded subset of the grid; halving
           runs cheap elimination rounds at reduced session budgets
           before evaluating survivors at full budget.  With --out DIR
           results persist as sharded JSONL: a killed sweep resumes
           from its completed shards and converges to the
           byte-identical front (--max-shards bounds the work of one
           invocation).  Every record embeds its full ServeSpec and
           state-hash, so any point replays via serve-gen --spec.
           --search FILE loads a serialized search JSON; flags layer
           over it
  config   print the default configuration as JSON
  help     this text

Models: Transformer-base, BERT-base, ALBERT-base, ViT-base, OPT-350
";

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn build_config(args: &[String]) -> Result<ArtemisConfig> {
    let mut cfg = if let Some(path) = flag_value(args, "--config") {
        ArtemisConfig::from_json(&std::fs::read_to_string(path)?)?
    } else {
        ArtemisConfig::default()
    };
    if let Some(stacks) = flag_value(args, "--stacks") {
        let n: u64 = stacks.parse()?;
        cfg = ArtemisConfig::with_stacks(n);
    }
    Ok(cfg)
}

fn run_serve(args: &[String]) -> Result<()> {
    let n: usize = flag_value(args, "--requests")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(256);
    let variant = flag_value(args, "--variant").unwrap_or_else(|| "q8sc".into());
    let cfg = build_config(args)?;
    let mut registry = ArtifactRegistry::open_default()?;
    println!("runtime backend: {}", registry.backend_name());
    let mut coord = Coordinator::new(&mut registry, &cfg, &variant)?;

    let seq = coord.seq_len();
    let mut rng = XorShift64::new(7);
    let requests: Vec<InferenceRequest> = (0..n as u64)
        .map(|id| InferenceRequest {
            id,
            tokens: (0..seq).map(|_| rng.below(32) as f32).collect(),
            enqueued_ns: coord.now_ns(),
        })
        .collect();

    let (responses, stats) = coord.serve_all(requests)?;
    println!(
        "served {} requests in {} batches ({} padded rows)",
        stats.requests, stats.batches, stats.padded_rows
    );
    println!(
        "wall: total {:.2} ms, exec {:.2} ms, throughput {:.0} req/s",
        stats.wall_total_ns as f64 * 1e-6,
        stats.wall_exec_ns as f64 * 1e-6,
        stats.wall_throughput_rps()
    );
    println!(
        "simulated ARTEMIS: {:.3} ms total, {:.3} mJ, {:.0} req/s",
        stats.sim_total_ns * 1e-6,
        stats.sim_total_pj * 1e-9,
        stats.sim_throughput_rps()
    );
    let mean_queue = responses.iter().map(|r| r.wall_queue_ns).sum::<u64>() as f64
        / responses.len().max(1) as f64;
    println!("mean wall queue delay: {:.2} ms", mean_queue * 1e-6);
    println!(
        "wall latency p50/p95/p99: {:.2}/{:.2}/{:.2} ms ({} short-row padded elems)",
        stats.wall_latency.p50 as f64 * 1e-6,
        stats.wall_latency.p95 as f64 * 1e-6,
        stats.wall_latency.p99 as f64 * 1e-6,
        stats.padded_elems
    );
    Ok(())
}

fn run_serve_gen(args: &[String]) -> Result<()> {
    // --spec FILE seeds the request from a serialized ServeSpec
    // document; explicit flags layer over it.  Bare flags parse over
    // the defaults — byte-identical to the historical flag loop.
    let base = match flag_value(args, "--spec") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)?;
            ServeSpec::from_json(&Json::parse(&text)?)?
        }
        None => ServeSpec::default(),
    };
    let spec = ServeSpec::from_args_over(base, args)?;
    run_serve_gen_spec(&spec)
}

/// Execute one validated [`ServeSpec`] — the shared path behind
/// `serve-gen` flags, `--spec` files, and the daemon's one-shot runs.
fn run_serve_gen_spec(spec: &ServeSpec) -> Result<()> {
    let resolved = spec.resolve()?;
    let sc = resolved.scenario;
    let batch = resolved.batch;
    let tc = resolved.tc;
    let seed = spec.seed;
    let trace_path = spec.trace.path.as_deref();

    let meta = meta_for(&sc, seed, sc.sessions as u64);
    if sc.sessions == 0 {
        println!(
            "## serve-gen — scenario '{}' seed {}: empty trace (0 sessions), nothing to serve",
            sc.name, seed
        );
        // An empty run still writes a *valid* trace (header + SLO
        // verdict + footer, all no-data, no NaN) so downstream
        // trace-report pipelines never see a truncated file.
        if let Some(path) = trace_path {
            let doc = build_trace(Vec::new(), &tc, &meta);
            write_trace(path, &doc)?;
        }
        return Ok(());
    }
    let sched = spec.sched(batch);

    // Cluster mode: any of the scale-out flags (or a spec `cluster`
    // section) switches `--stacks` from "one bigger machine" (the
    // fig12 meaning elsewhere) to "D cluster stacks, each a
    // default/--config machine".
    if let Some(cl_spec) = spec.cluster {
        let stack_cfg = spec.load_stack_config()?;
        let d = cl_spec.stacks;
        let placement = cl_spec.placement;
        let route = cl_spec.route;
        let cached = cl_spec.cost_cache;
        let cl = cl_spec.to_cluster_config(spec.engine);
        // Tracing needs the materialized trace (span builders index into
        // it); the untraced path streams arrivals and stays O(active).
        let (r, doc) = if trace_path.is_some() {
            let trace = sc.generate(seed);
            let (r, doc) = run_cluster_traced(
                &stack_cfg,
                &sc.model,
                &trace,
                &cl,
                &sched,
                route,
                cached,
                &tc,
                &meta,
            );
            (r, Some(doc))
        } else {
            let r = run_cluster_stream(
                &stack_cfg,
                &sc.model,
                sc.stream(seed),
                &cl,
                &sched,
                route,
                cached,
            );
            (r, None)
        };

        println!(
            "## serve-gen cluster — scenario '{}' seed {} ({}, {} sessions, {} stacks {}, \
             route {}, batch {}, policy {}, qos {}, engine {}, cost-cache {})",
            sc.name,
            seed,
            sc.model.name,
            sc.sessions,
            d,
            placement,
            route,
            batch,
            spec.policy,
            sc.qos,
            spec.engine,
            if cached { "on" } else { "off" }
        );
        let mut reports = r.per_stack.clone();
        reports.push(r.aggregate.clone());
        report::serving_comparison(&reports).print();
        println!(
            "aggregate: {:.0} tokens/s   makespan {:.3} ms   energy {:.3} mJ   rejected {}",
            r.tokens_per_s(),
            r.aggregate.makespan_ns * 1e-6,
            r.aggregate.sim_energy_pj * 1e-9,
            r.aggregate.rejected
        );
        println!(
            "cost-cache: {} — hits {}  misses {}  hit-rate {:.1}%",
            if cached { "on" } else { "off" },
            r.cache.hits,
            r.cache.misses,
            r.cache.hit_rate() * 100.0
        );
        // One u64 over the whole simulated outcome: equal across
        // engines, thread counts, and cache on/off by construction.
        println!("state-hash {:#018x}", r.state_hash());
        if let (Some(path), Some(doc)) = (trace_path, &doc) {
            write_trace(path, doc)?;
        }
        return Ok(());
    }

    let cfg = spec.load_stack_config()?;
    let (cont, doc) = if trace_path.is_some() {
        let trace = sc.generate(seed);
        let (r, doc) =
            run_continuous_traced(&cfg, &sc.model, &trace, &sched, spec.engine, &tc, &meta);
        (r, Some(doc))
    } else {
        (run_continuous_stream(&cfg, &sc.model, sc.stream(seed), &sched, spec.engine), None)
    };
    let stat = run_static_stream(&cfg, &sc.model, sc.stream(seed), batch);

    println!(
        "## serve-gen — scenario '{}' seed {} ({}, {} sessions, batch {}, policy {}, qos {}, \
         engine {})",
        sc.name,
        seed,
        sc.model.name,
        sc.sessions,
        batch,
        spec.policy,
        sc.qos,
        spec.engine
    );
    for r in [&cont, &stat] {
        println!("{}:", r.scheme);
        println!(
            "  ttft            p50 {:>12.0} ns  p95 {:>12.0} ns  p99 {:>12.0} ns",
            r.ttft.p50, r.ttft.p95, r.ttft.p99
        );
        println!(
            "  per-token       p50 {:>12.0} ns  p95 {:>12.0} ns  p99 {:>12.0} ns  mean {:.0} ns",
            r.per_token.p50, r.per_token.p95, r.per_token.p99, r.per_token.mean
        );
        println!(
            "  inter-token gap p50 {:>12.0} ns  p95 {:>12.0} ns  p99 {:>12.0} ns",
            r.itl.p50, r.itl.p95, r.itl.p99
        );
        println!(
            "  est accuracy    p50 {:>12.4}     p10 {:>12.4}     min {:>12.4}    mean {:.4}",
            r.accuracy.p50, r.accuracy.p10, r.accuracy.min, r.accuracy.mean
        );
        println!(
            "  tokens/s {:.0}   makespan {:.3} ms   energy {:.3} mJ   \
             mean batch {:.2}   peak KV/bank {:.2} MB (budget {:.2} MB)   rejected {}",
            r.tokens_per_s(),
            r.makespan_ns * 1e-6,
            r.sim_energy_pj * 1e-9,
            r.mean_batch,
            r.peak_kv_per_bank as f64 * 1e-6,
            r.kv_budget_per_bank as f64 * 1e-6,
            r.rejected
        );
        println!("  state-hash {:#018x}", r.state_hash());
    }
    println!();
    report::serving_comparison(&[cont, stat]).print();
    if let (Some(path), Some(doc)) = (trace_path, &doc) {
        write_trace(path, doc)?;
    }
    Ok(())
}

/// Emit a built trace as JSONL and print the grep-stable summary and
/// verdict lines CI asserts on.
fn write_trace(path: &str, doc: &Trace) -> Result<()> {
    let mut sink = FileSink::create(std::path::Path::new(path))?;
    doc.emit(&mut sink);
    println!(
        "trace: wrote {path} ({} spans, {} windows, schema v{SCHEMA_VERSION})",
        doc.spans.len(),
        doc.windows.len()
    );
    println!("{}", doc.slo.verdict_line());
    Ok(())
}

/// `trace-report`: replay a JSONL trace file into human-readable
/// tables (see `report::print_trace_report`).
fn run_trace_report(args: &[String]) -> Result<()> {
    let path = args
        .get(1)
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| anyhow!("usage: artemis trace-report <trace.jsonl> [--top K]"))?;
    let top: usize = flag_value(args, "--top").map(|v| v.parse()).transpose()?.unwrap_or(5);
    let text = std::fs::read_to_string(path)?;
    let parsed = parse_trace(&text)?;
    println!("## trace-report — {path}");
    report::print_trace_report(&parsed, top);
    Ok(())
}

/// The CI perf gate: time the seeded scale-out serve suite — every
/// scenario (chat/summarize/burst) x placement (dp/pp) x cost cache
/// (on/off), each at seed 1 on 4 stacks with the scenario's default
/// session count, plus the idle-heavy `long_itl` point under both
/// clock-advance engines — and write one consolidated JSON artifact.
/// `wall_ms` is the best of `--reps` runs (noise floor);
/// `sim_tokens_per_s` is trace-tokens simulated per wall-second — the
/// throughput of the *simulator*, which the sharded cache, the
/// parallel driver and the allocation-lean tick loop are meant to buy.
/// `--threads` pins the driver pool (0 = auto, 1 = the serial
/// reference path CI also records); simulated outputs are identical
/// either way, only wall-clock moves.
fn run_bench_serve(args: &[String]) -> Result<()> {
    let out = flag_value(args, "--out").unwrap_or_else(|| "BENCH_serve.json".into());
    let reps: usize =
        flag_value(args, "--reps").map(|v| v.parse()).transpose()?.unwrap_or(3).max(1);
    let threads: usize =
        flag_value(args, "--threads").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let cfg = ArtemisConfig::default();
    let seed = 1u64;
    let stacks = 4u64;

    let mut benches: Vec<Json> = Vec::new();
    for scenario in ["chat", "summarize", "burst"] {
        for placement in [Placement::DataParallel, Placement::PipelineParallel] {
            for cached in [true, false] {
                let sc = Scenario::by_name(scenario).expect("built-in scenario");
                let name = format!(
                    "{scenario}_{placement}_{}",
                    if cached { "cache" } else { "nocache" }
                );
                let mut best_ms = f64::INFINITY;
                let mut tokens = 0u64;
                for _ in 0..reps {
                    let t0 = std::time::Instant::now();
                    let r =
                        run_scenario_cluster(&cfg, &sc, stacks, placement, seed, cached, threads);
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    tokens = r.aggregate.total_tokens;
                    best_ms = best_ms.min(ms);
                }
                let tok_per_wall_s = tokens as f64 / (best_ms.max(1e-9) * 1e-3);
                println!(
                    "bench {name}: wall {best_ms:.3} ms (best of {reps}), {tokens} trace \
                     tokens, {tok_per_wall_s:.0} sim tokens per wall-second"
                );
                benches.push(Json::obj(vec![
                    ("bench", Json::Str(name)),
                    ("wall_ms", Json::Num((best_ms * 1e3).round() / 1e3)),
                    ("sim_tokens_per_s", Json::Num((tok_per_wall_s * 10.0).round() / 10.0)),
                ]));
            }
        }
    }

    // Idle-heavy long-ITL point, tick vs event engine: a deep SPF wait
    // queue with a tiny batch is the regime the event engine's
    // scan-skip targets, and the bench pair is CI's record of that win
    // (the gate script asserts event is >= 3x faster).  Same trace,
    // same shape — the state hashes must match bit-for-bit.
    let lsc = Scenario::long_itl();
    let ltrace = lsc.generate(seed);
    let lsched =
        SchedulerConfig { max_batch: lsc.max_batch, policy: Policy::ShortestPromptFirst };
    let mut hashes: Vec<u64> = Vec::new();
    let mut profile = PhaseProfile::default();
    let mut long_itl_event_ms = f64::INFINITY;
    for engine in [EngineStrategy::Tick, EngineStrategy::Event] {
        let name = format!("long_itl_{engine}");
        let cl = ClusterConfig::new(1, Placement::DataParallel)
            .with_threads(threads)
            .with_engine(engine);
        let mut best_ms = f64::INFINITY;
        let mut tokens = 0u64;
        let mut hash = 0u64;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let r = run_cluster(
                &cfg,
                &lsc.model,
                &ltrace,
                &cl,
                &lsched,
                RoutePolicy::LeastLoaded,
                true,
            );
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            tokens = r.aggregate.total_tokens;
            hash = r.state_hash();
            if engine == EngineStrategy::Event {
                profile = r.profile;
            }
            best_ms = best_ms.min(ms);
        }
        hashes.push(hash);
        if engine == EngineStrategy::Event {
            long_itl_event_ms = best_ms;
        }
        let tok_per_wall_s = tokens as f64 / (best_ms.max(1e-9) * 1e-3);
        println!(
            "bench {name}: wall {best_ms:.3} ms (best of {reps}), {tokens} trace \
             tokens, {tok_per_wall_s:.0} sim tokens per wall-second, \
             state-hash {hash:#018x}"
        );
        benches.push(Json::obj(vec![
            ("bench", Json::Str(name)),
            ("wall_ms", Json::Num((best_ms * 1e3).round() / 1e3)),
            ("sim_tokens_per_s", Json::Num((tok_per_wall_s * 10.0).round() / 10.0)),
        ]));
    }
    if hashes[0] != hashes[1] {
        return Err(anyhow!(
            "engine divergence: tick state-hash {:#018x} != event {:#018x}",
            hashes[0],
            hashes[1]
        ));
    }

    // Telemetry overhead: re-time the long_itl event point with the
    // full trace pipeline enabled and the emitted JSONL discarded into
    // a null sink.  The ratio is the per-run cost of tracing; CI's
    // perf gate holds null_sink_wall_ms to the same 2x ceiling as the
    // untraced point, and the state hash must not move.
    let telemetry = {
        let cl = ClusterConfig::new(1, Placement::DataParallel)
            .with_threads(threads)
            .with_engine(EngineStrategy::Event);
        let ttc = TraceConfig::default();
        let tmeta = TraceMeta {
            scenario: lsc.name.to_string(),
            model: lsc.model.name.clone(),
            seed: Some(seed),
            sessions: ltrace.len() as u64,
            qos: lsc.qos.to_string(),
        };
        let mut best_ms = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let (r, doc) = run_cluster_traced(
                &cfg,
                &lsc.model,
                &ltrace,
                &cl,
                &lsched,
                RoutePolicy::LeastLoaded,
                true,
                &ttc,
                &tmeta,
            );
            doc.emit(&mut NullSink);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            if r.state_hash() != hashes[1] {
                return Err(anyhow!(
                    "telemetry moved the state hash: {:#018x} != {:#018x}",
                    r.state_hash(),
                    hashes[1]
                ));
            }
            best_ms = best_ms.min(ms);
        }
        let ratio = best_ms / long_itl_event_ms.max(1e-9);
        println!(
            "bench long_itl_event+telemetry(null sink): wall {best_ms:.3} ms \
             (best of {reps}), {ratio:.2}x the untraced run"
        );
        Json::obj(vec![
            ("bench", Json::Str("long_itl_event".into())),
            ("off_wall_ms", Json::Num((long_itl_event_ms * 1e3).round() / 1e3)),
            ("null_sink_wall_ms", Json::Num((best_ms * 1e3).round() / 1e3)),
            ("overhead_ratio", Json::Num((ratio * 1e3).round() / 1e3)),
        ])
    };

    // `threads` records the *request* (0 = auto): dp points resolve it
    // to min(stacks, machine parallelism), pp points to 1 (one logical
    // replica) — simulated outputs are identical regardless.
    let n_benches = benches.len();
    let mut fields = vec![
        ("suite", Json::Str("serve_gen_cluster_x4_seed1".into())),
        ("threads", Json::Num(threads as f64)),
        ("benches", Json::Arr(benches)),
        ("telemetry", telemetry),
    ];
    // Process-lifetime peak RSS (VmHWM) as a top-level artifact field —
    // a memory trend line next to the wall-clock one.  Not a `benches`
    // entry: the perf gate pins the bench-name set to the baseline.
    if let Some(rss) = artemis::util::bench::peak_rss_bytes() {
        fields.push(("peak_rss_bytes", Json::Num(rss as f64)));
    }
    // Per-phase wall-time profile of the long_itl event run, against
    // the stated scheduler-overhead budget.  All-zero (and omitted)
    // unless built with `--features profiling`.
    if cfg!(feature = "profiling") {
        let per_tick = |i: usize| {
            if profile.ticks == 0 {
                0.0
            } else {
                ((profile.ns[i] as f64 / profile.ticks as f64) * 10.0).round() / 10.0
            }
        };
        fields.push((
            "profile",
            Json::obj(vec![
                ("bench", Json::Str("long_itl_event".into())),
                ("ticks", Json::Num(profile.ticks as f64)),
                (
                    "budget_ns_per_tick",
                    Json::Num(PhaseProfile::BUDGET_NS_PER_TICK as f64),
                ),
                (
                    "overhead_ns_per_tick",
                    Json::Num((profile.overhead_ns_per_tick() * 10.0).round() / 10.0),
                ),
                (
                    "phases_ns_per_tick",
                    Json::obj(
                        PhaseProfile::PHASE_NAMES
                            .iter()
                            .enumerate()
                            .map(|(i, &n)| (n, Json::Num(per_tick(i))))
                            .collect(),
                    ),
                ),
            ]),
        ));
    }
    let doc = Json::obj(fields);
    std::fs::write(&out, doc.pretty() + "\n")?;
    println!("wrote {out} ({n_benches} benches, requested threads {threads} [0=auto])");
    Ok(())
}

/// `bench-scale`: the streaming-core scale lane.  Serves one scenario
/// at each requested session count through *both* clock-advance
/// engines using the lazy arrival stream ([`Scenario::stream`]) and
/// the slab-backed session store, so memory stays O(active sessions +
/// bounded accumulators) no matter how long the trace is.  Per point
/// it records wall-clock, sessions per wall-second, and the process
/// peak RSS (VmHWM), and asserts tick/event state-hash equality.
///
/// VmHWM is a process-*lifetime* high-water mark, so the points must
/// be ascending: each point's reading then reflects the largest run
/// so far, and the adjacent-point ratio gate (>= 10x the sessions
/// must cost < 3x the peak RSS) is meaningful.  The gate failing —
/// or `--max-rss-mb` being exceeded — is a hard error, which is how
/// CI turns this lane into the sub-linear-memory regression check.
fn run_bench_scale(args: &[String]) -> Result<()> {
    let out = flag_value(args, "--out").unwrap_or_else(|| "BENCH_scale.json".into());
    let scenario = flag_value(args, "--scenario").unwrap_or_else(|| "chat".into());
    let seed: u64 = flag_value(args, "--seed").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let csv = flag_value(args, "--sessions").unwrap_or_else(|| "10000,100000".into());
    let max_rss_mb: Option<u64> =
        flag_value(args, "--max-rss-mb").map(|v| v.parse()).transpose()?;
    let points: Vec<usize> = csv
        .split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("--sessions '{s}': {e}")))
        .collect::<Result<_>>()?;
    if points.is_empty() {
        return Err(anyhow!("--sessions needs at least one count"));
    }
    if points.windows(2).any(|w| w[1] <= w[0]) {
        return Err(anyhow!(
            "--sessions counts must be strictly ascending (peak RSS is a \
             process-lifetime high-water mark, so later points must be the bigger runs)"
        ));
    }
    let base = Scenario::by_name(&scenario)
        .ok_or_else(|| anyhow!("unknown scenario '{scenario}'"))?;
    let cfg = ArtemisConfig::default();

    let mut rows: Vec<Json> = Vec::new();
    let mut rss_points: Vec<(usize, u64)> = Vec::new();
    for &n in &points {
        let sc = base.clone().with_sessions(n);
        let sched = SchedulerConfig { max_batch: sc.max_batch, policy: Policy::Fifo };
        let mut walls = [0.0f64; 2];
        let mut hashes = [0u64; 2];
        for (i, engine) in [EngineStrategy::Tick, EngineStrategy::Event].into_iter().enumerate() {
            // One stack through the memoized cost cache — the
            // bench-serve long_itl idiom; per-tick work is a cache
            // lookup, so wall-clock tracks the scheduler, not the
            // transformer cost model.
            let cl = ClusterConfig::new(1, Placement::DataParallel)
                .with_threads(1)
                .with_engine(engine);
            let t0 = std::time::Instant::now();
            let r = run_cluster_stream(
                &cfg,
                &sc.model,
                sc.stream(seed),
                &cl,
                &sched,
                RoutePolicy::LeastLoaded,
                true,
            );
            walls[i] = t0.elapsed().as_secs_f64() * 1e3;
            hashes[i] = r.state_hash();
        }
        if hashes[0] != hashes[1] {
            return Err(anyhow!(
                "engine divergence at {n} sessions: tick state-hash {:#018x} != event {:#018x}",
                hashes[0],
                hashes[1]
            ));
        }
        let best_ms = walls[0].min(walls[1]);
        let sessions_per_s = n as f64 / (best_ms.max(1e-9) * 1e-3);
        let rss = artemis::util::bench::peak_rss_bytes();
        let rss_str = match rss {
            Some(b) => format!("{:.1} MB", b as f64 / (1u64 << 20) as f64),
            None => "n/a".to_string(),
        };
        println!(
            "bench-scale {scenario} {n} sessions: tick {:.1} ms, event {:.1} ms, \
             {sessions_per_s:.0} sessions per wall-second, peak RSS {rss_str}, \
             state-hash {:#018x}",
            walls[0], walls[1], hashes[0]
        );
        let mut row = vec![
            ("sessions", Json::Num(n as f64)),
            ("wall_ms_tick", Json::Num((walls[0] * 1e3).round() / 1e3)),
            ("wall_ms_event", Json::Num((walls[1] * 1e3).round() / 1e3)),
            ("sessions_per_s", Json::Num((sessions_per_s * 10.0).round() / 10.0)),
        ];
        if let Some(b) = rss {
            row.push(("peak_rss_bytes", Json::Num(b as f64)));
            rss_points.push((n, b));
        }
        rows.push(Json::obj(row));
    }

    // Sub-linear-memory gate: a 10x (or more) jump in sessions must
    // not cost 3x the peak RSS — O(active)-memory serving keeps the
    // resident set pinned to active sessions + bounded accumulators,
    // so RSS should barely move while the trace grows by decades.
    for w in rss_points.windows(2) {
        let ((n0, r0), (n1, r1)) = (w[0], w[1]);
        if n1 >= n0.saturating_mul(10) && r1 >= r0.saturating_mul(3) {
            return Err(anyhow!(
                "super-linear memory growth: {n0} -> {n1} sessions grew peak RSS \
                 {r0} -> {r1} bytes (>= 3x); the streaming core should hold RSS \
                 near-flat across session decades"
            ));
        }
    }
    if let (Some(cap_mb), Some(&(_, peak))) = (max_rss_mb, rss_points.last()) {
        if peak > cap_mb.saturating_mul(1 << 20) {
            return Err(anyhow!(
                "peak RSS {peak} bytes exceeds the --max-rss-mb {cap_mb} MiB ceiling"
            ));
        }
    }

    let doc = Json::obj(vec![
        ("suite", Json::Str("serve_scale_stream".into())),
        ("scenario", Json::Str(scenario.clone())),
        ("seed", Json::Num(seed as f64)),
        ("points", Json::Arr(rows)),
    ]);
    std::fs::write(&out, doc.pretty() + "\n")?;
    println!("wrote {out} ({} points, scenario {scenario}, seed {seed})", points.len());
    Ok(())
}

/// `design-search`: run (or resume) a design-space sweep and print the
/// Pareto front.  The serializable [`SearchSpec`] carries everything
/// that shapes the results; `--out`, `--threads` and `--max-shards`
/// only steer this invocation.
fn run_design_search(args: &[String]) -> Result<()> {
    let spec = SearchSpec::from_args(args)?;
    let opts = RunOptions {
        out: flag_value(args, "--out").map(std::path::PathBuf::from),
        threads: flag_value(args, "--threads").map(|v| v.parse()).transpose()?.unwrap_or(0),
        max_shards: flag_value(args, "--max-shards").map(|v| v.parse()).transpose()?,
    };
    println!(
        "## design-search — {} sampler over a {}-point grid, {} shards, cost-cache {}{}",
        spec.sampler,
        spec.grid_size(),
        spec.shards,
        if spec.cost_cache { "on" } else { "off" },
        match &opts.out {
            Some(dir) => format!(", out {}", dir.display()),
            None => String::new(),
        }
    );
    let t0 = std::time::Instant::now();
    let outcome = run_search(&spec, &opts, &mut |e| {
        println!(
            "design-search: shard {}/{} {} ({} candidates)",
            e.shard + 1,
            e.shards,
            e.outcome,
            e.candidates
        );
    })?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    if outcome.complete {
        println!();
        report::search_front_table(&outcome.front).print();
        println!(
            "design-search: {} candidates -> {} front points ({} shards: {} evaluated, \
             {} reused) in {:.1} ms",
            outcome.candidates_total,
            outcome.front.len(),
            outcome.shards_total,
            outcome.shards_evaluated,
            outcome.shards_reused,
            wall_ms
        );
        println!("front-hash {:#018x}", outcome.front_hash);
    } else {
        println!(
            "design-search: incomplete — {} of {} shards done, {} skipped by --max-shards; \
             rerun with the same --out to resume",
            outcome.shards_reused + outcome.shards_evaluated,
            outcome.shards_total,
            outcome.shards_skipped
        );
    }

    // Perf-lane artifact: configs evaluated per wall-second, this
    // invocation (reused shards cost ~nothing and are excluded).
    if let Some(out) = flag_value(args, "--bench-out") {
        let per_s = outcome.evaluated_candidates as f64 / (wall_ms.max(1e-9) * 1e-3);
        let doc = Json::obj(vec![
            ("suite", Json::Str("design_search".into())),
            ("configs", Json::Num(outcome.evaluated_candidates as f64)),
            ("wall_ms", Json::Num((wall_ms * 1e3).round() / 1e3)),
            ("configs_per_s", Json::Num((per_s * 10.0).round() / 10.0)),
            ("threads", Json::Num(opts.threads as f64)),
        ]);
        std::fs::write(&out, doc.pretty() + "\n")?;
        println!("wrote {out} ({} configs evaluated)", outcome.evaluated_candidates);
    }
    Ok(())
}

fn run_tab4() -> Result<()> {
    let mut registry = ArtifactRegistry::open_default()?;
    let results = evaluate_variants(&mut registry, 64, 0x7AB4)?;
    let mut t = report::TableBuilder::new(
        "Table IV — accuracy by arithmetic variant (synthetic proxy task; the \
         observable is the FP32->Q8->Q8+SC delta)",
        &["variant", "accuracy", "samples", "delta vs fp32", "logit MAE vs fp32"],
    );
    let fp32 = results
        .iter()
        .find(|r| r.variant == "fp32")
        .map(|r| r.accuracy)
        .unwrap_or(0.0);
    for r in &results {
        t.row(vec![
            r.variant.clone(),
            format!("{:.4}", r.accuracy),
            r.samples.to_string(),
            format!("{:+.4}", r.accuracy - fp32),
            format!("{:.4}", r.logit_mae_vs_fp32),
        ]);
    }
    t.print();
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    // design-search owns its flag vocabulary (`--stacks` is a CSV axis
    // there, not this shared machine-size override).
    let cfg = if cmd == "design-search" {
        ArtemisConfig::default()
    } else {
        build_config(&args)?
    };

    match cmd {
        "fig2" => report::fig2(&cfg).print(),
        "fig7" => report::fig7().print(),
        "fig8" => report::fig8(&cfg).print(),
        "fig9" => report::fig9(&cfg).print(),
        "fig10" => report::fig10(&cfg).print(),
        "fig11" => report::fig11(&cfg).print(),
        "fig12" => report::fig12().print(),
        "tab3" => report::tab3(&cfg).print(),
        "tab4" => run_tab4()?,
        "tab5" => report::tab5(&cfg).print(),
        "micro" => report::micro(&cfg).print(),
        "decode" => report::decode_study(&cfg).print(),
        "noise" => report::noise_study().print(),
        "ablation" => report::ablation_deterministic_vs_lfsr().print(),
        "capacity" => report::capacity_study().print(),
        "fidelity-sweep" => {
            report::fidelity_pareto(&cfg).print();
            report::qos_serving_study(&cfg).print();
        }
        "csv" => {
            let outdir = flag_value(&args, "--outdir").unwrap_or_else(|| "results".into());
            std::fs::create_dir_all(&outdir)?;
            let tables: Vec<(&str, report::TableBuilder)> = vec![
                ("fig2", report::fig2(&cfg)),
                ("tab3", report::tab3(&cfg)),
                ("tab5", report::tab5(&cfg)),
                ("fig7", report::fig7()),
                ("fig8", report::fig8(&cfg)),
                ("fig9", report::fig9(&cfg)),
                ("fig10", report::fig10(&cfg)),
                ("fig11", report::fig11(&cfg)),
                ("fig12", report::fig12()),
                ("micro", report::micro(&cfg)),
                ("decode", report::decode_study(&cfg)),
                ("noise", report::noise_study()),
                ("ablation", report::ablation_deterministic_vs_lfsr()),
                ("capacity", report::capacity_study()),
                ("fidelity", report::fidelity_pareto(&cfg)),
                ("serving_qos", report::qos_serving_study(&cfg)),
                ("serving", report::serving_study(&cfg)),
                ("cluster_scale", report::cluster_scale_study(&cfg)),
            ];
            for (name, t) in tables {
                let path = format!("{outdir}/{name}.csv");
                std::fs::write(&path, t.to_csv())?;
                println!("wrote {path}");
            }
        }
        "all" => {
            report::micro(&cfg).print();
            report::fig2(&cfg).print();
            report::tab3(&cfg).print();
            report::tab5(&cfg).print();
            report::fig7().print();
            report::fig8(&cfg).print();
            report::fig9(&cfg).print();
            report::fig10(&cfg).print();
            report::fig11(&cfg).print();
            report::fig12().print();
            report::decode_study(&cfg).print();
            report::noise_study().print();
            report::ablation_deterministic_vs_lfsr().print();
            report::capacity_study().print();
            report::fidelity_pareto(&cfg).print();
            report::qos_serving_study(&cfg).print();
            report::serving_study(&cfg).print();
            report::cluster_scale_study(&cfg).print();
            if let Err(e) = run_tab4() {
                eprintln!("tab4 skipped (artifacts missing?): {e}");
            }
        }
        "simulate" => {
            let model = flag_value(&args, "--model").unwrap_or_else(|| "BERT-base".into());
            let dataflow = match flag_value(&args, "--dataflow").as_deref() {
                Some("layer") => Dataflow::Layer,
                _ => Dataflow::Token,
            };
            let pipelining = if has_flag(&args, "--no-pipeline") {
                Pipelining::Off
            } else {
                Pipelining::On
            };
            match report::model_report(&cfg, &model, SimOptions { dataflow, pipelining }) {
                Some(t) => t.print(),
                None => {
                    eprintln!("unknown model '{model}' — see `artemis help`");
                    std::process::exit(1);
                }
            }
        }
        "serve" => run_serve(&args)?,
        "serve-gen" => run_serve_gen(&args)?,
        "serve-daemon" => run_daemon(&args)?,
        "trace-report" => run_trace_report(&args)?,
        "cluster-scale" => report::cluster_scale_study(&cfg).print(),
        "bench-serve" => run_bench_serve(&args)?,
        "bench-scale" => run_bench_scale(&args)?,
        "design-search" => run_design_search(&args)?,
        "config" => println!("{}", cfg.to_json()),
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            std::process::exit(1);
        }
    }
    Ok(())
}
