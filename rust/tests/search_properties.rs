//! Determinism and correctness properties of the design-search engine.
//!
//! The contract under test (DESIGN.md §Design-search):
//!
//! * Pareto extraction is *exact* — set-identical to a brute-force
//!   dominance scan.
//! * A killed-and-resumed sweep converges to byte-identical shard files
//!   and front as an uninterrupted run, at every `--threads` value.
//! * Successive halving returns records bit-identical to the exhaustive
//!   sweep's for the surviving ids, and its front is a subset of the
//!   exhaustive front.
//! * Every persisted record's embedded `ServeSpec` replays through the
//!   plain cluster path (`serve-gen --spec`) to the same `state_hash`.
//! * The sweep-shared cost cache never changes a result bit.

use artemis::cluster::run_cluster;
use artemis::config::Placement;
use artemis::search::{
    pareto_front, run_search, AxisSpec, Objectives, RunOptions, SamplerKind, SearchSpec,
};
use artemis::serve::{QosAssignment, QosTier, ServeSpec};
use artemis::util::XorShift64;
use std::path::PathBuf;

/// A 4-point sweep (2 stream lengths × 2 noise levels, one dp stack,
/// 3 chat sessions) split unevenly over 3 shards.
fn tiny_spec() -> SearchSpec {
    let d = SearchSpec::default();
    SearchSpec {
        base: ServeSpec { sessions: Some(3), ..d.base.clone() },
        axes: AxisSpec {
            stream_lens: vec![32, 128],
            sigmas: vec![0.0, 2.0],
            stacks: vec![1],
            placements: vec![Placement::DataParallel],
            hops_ns: vec![40.0],
            qos: vec![QosAssignment::Uniform(QosTier::Gold)],
        },
        shards: 3,
        ..d
    }
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("artemis-search-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn read(p: PathBuf) -> Vec<u8> {
    std::fs::read(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

#[test]
fn front_extraction_matches_brute_force() {
    // Synthetic objective cloud: the extractor must agree exactly with
    // the O(n²) dominance definition.
    let mut rng = XorShift64::new(7);
    let objs: Vec<Objectives> = (0..64)
        .map(|_| Objectives {
            accuracy: rng.below(1000) as f64 / 1000.0,
            tokens_per_s: rng.below(1000) as f64 + 1.0,
            mj_per_token: rng.below(1000) as f64 / 10.0 + 0.1,
        })
        .collect();
    let front = pareto_front(&objs);
    assert!(!front.is_empty());
    for (i, o) in objs.iter().enumerate() {
        let dominated = objs.iter().any(|p| p.dominates(o));
        assert_eq!(!dominated, front.contains(&i), "membership of index {i}");
    }
    // Same input, same front — extraction is deterministic.
    assert_eq!(front, pareto_front(&objs));
}

#[test]
fn resumed_sweep_is_byte_identical_to_uninterrupted() {
    let spec = tiny_spec();
    let full_dir = tmpdir("full");
    let full_opts = RunOptions { out: Some(full_dir.clone()), ..RunOptions::default() };
    let full = run_search(&spec, &full_opts, &mut |_| {}).unwrap();
    assert!(full.complete);

    // "Kill" a second sweep after every shard by budgeting one shard per
    // invocation; the last call assembles the front from reused files.
    let step_dir = tmpdir("step");
    let step_opts = RunOptions {
        out: Some(step_dir.clone()),
        threads: 2,
        max_shards: Some(1),
    };
    let mut last = run_search(&spec, &step_opts, &mut |_| {}).unwrap();
    let mut rounds = 1;
    while !last.complete {
        last = run_search(&spec, &step_opts, &mut |_| {}).unwrap();
        rounds += 1;
        assert!(rounds <= 8, "resume failed to converge");
    }
    assert_eq!(last.front_hash, full.front_hash);
    for s in 0..spec.shards.min(spec.grid_size()) {
        let name = format!("shard-{s:04}.jsonl");
        assert_eq!(read(full_dir.join(&name)), read(step_dir.join(&name)), "{name}");
    }
    assert_eq!(read(full_dir.join("front.jsonl")), read(step_dir.join("front.jsonl")));
    let _ = std::fs::remove_dir_all(&full_dir);
    let _ = std::fs::remove_dir_all(&step_dir);
}

#[test]
fn sweep_files_are_stable_across_thread_counts() {
    let spec = tiny_spec();
    let serial_dir = tmpdir("serial");
    let wide_dir = tmpdir("wide");
    let serial = run_search(
        &spec,
        &RunOptions { out: Some(serial_dir.clone()), threads: 1, ..RunOptions::default() },
        &mut |_| {},
    )
    .unwrap();
    let wide = run_search(
        &spec,
        &RunOptions { out: Some(wide_dir.clone()), threads: 3, ..RunOptions::default() },
        &mut |_| {},
    )
    .unwrap();
    assert_eq!(serial.front_hash, wide.front_hash);
    for s in 0..spec.shards.min(spec.grid_size()) {
        let name = format!("shard-{s:04}.jsonl");
        assert_eq!(read(serial_dir.join(&name)), read(wide_dir.join(&name)), "{name}");
    }
    assert_eq!(read(serial_dir.join("front.jsonl")), read(wide_dir.join("front.jsonl")));
    let _ = std::fs::remove_dir_all(&serial_dir);
    let _ = std::fs::remove_dir_all(&wide_dir);
}

#[test]
fn halving_front_is_a_subset_of_the_exhaustive_front() {
    let halving =
        SearchSpec { sampler: SamplerKind::Halving { rungs: 2 }, ..tiny_spec() };
    let sh = run_search(&halving, &RunOptions::default(), &mut |_| {}).unwrap();
    assert!(sh.complete);
    assert!(sh.candidates_total < halving.grid_size(), "halving must eliminate someone");

    let exhaustive = SearchSpec { sampler: SamplerKind::Grid, ..halving.clone() };
    let ex = run_search(&exhaustive, &RunOptions::default(), &mut |_| {}).unwrap();
    // Survivors were re-evaluated at the full budget, so their records
    // are bit-identical to the exhaustive sweep's.
    for r in &sh.results {
        let twin = ex.results.iter().find(|e| e.cand.id == r.cand.id).unwrap();
        assert_eq!(r.state_hash, twin.state_hash, "candidate {}", r.cand.id);
        assert_eq!(r.obj.accuracy.to_bits(), twin.obj.accuracy.to_bits());
        assert_eq!(r.obj.tokens_per_s.to_bits(), twin.obj.tokens_per_s.to_bits());
        assert_eq!(r.obj.mj_per_token.to_bits(), twin.obj.mj_per_token.to_bits());
    }
    let ex_front: Vec<u64> = ex.front.iter().map(|r| r.cand.id).collect();
    for f in &sh.front {
        assert!(ex_front.contains(&f.cand.id), "{} not in exhaustive front", f.cand.id);
    }
}

#[test]
fn record_specs_replay_to_the_same_state_hash() {
    // Acceptance check: a sweep record's embedded ServeSpec, replayed
    // through the plain `serve-gen --spec` cluster path (JSON round-trip
    // included), lands on the record's state_hash.
    let spec = tiny_spec();
    let out = run_search(&spec, &RunOptions::default(), &mut |_| {}).unwrap();
    assert!(out.complete && !out.front.is_empty());
    for r in &out.front {
        let embedded = spec.candidate_spec(&r.cand);
        let cspec = ServeSpec::from_json(&embedded.to_json()).unwrap();
        assert_eq!(cspec, embedded, "candidate spec JSON round-trip");
        let cfg = cspec.load_stack_config().unwrap();
        let resolved = cspec.resolve().unwrap();
        let trace = resolved.scenario.generate(cspec.seed);
        let sched = cspec.sched(resolved.batch);
        let cl = cspec.cluster.expect("candidate specs carry a cluster section");
        let cluster = cl.to_cluster_config(cspec.engine);
        let report = run_cluster(
            &cfg,
            &resolved.scenario.model,
            &trace,
            &cluster,
            &sched,
            cl.route,
            cl.cost_cache,
        );
        assert_eq!(report.state_hash(), r.state_hash, "candidate {}", r.cand.id);
    }
}

#[test]
fn shared_cost_cache_never_changes_a_bit() {
    let cached = tiny_spec();
    let uncached = SearchSpec { cost_cache: false, ..cached.clone() };
    let a = run_search(&cached, &RunOptions::default(), &mut |_| {}).unwrap();
    let b = run_search(&uncached, &RunOptions::default(), &mut |_| {}).unwrap();
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.cand.id, y.cand.id);
        assert_eq!(x.state_hash, y.state_hash, "candidate {}", x.cand.id);
        assert_eq!(x.obj.accuracy.to_bits(), y.obj.accuracy.to_bits());
        assert_eq!(x.obj.tokens_per_s.to_bits(), y.obj.tokens_per_s.to_bits());
        assert_eq!(x.obj.mj_per_token.to_bits(), y.obj.mj_per_token.to_bits());
    }
    // Same objectives, same front membership (the front *files* differ
    // only through the embedded spec's cost_cache flag).
    let fa: Vec<u64> = a.front.iter().map(|r| r.cand.id).collect();
    let fb: Vec<u64> = b.front.iter().map(|r| r.cand.id).collect();
    assert_eq!(fa, fb);
}

#[test]
fn search_spec_round_trips_and_rejects_bad_input() {
    let s = tiny_spec();
    let j = s.to_json();
    let back = SearchSpec::from_json(&j).unwrap();
    assert_eq!(back, s);
    assert_eq!(back.to_json().compact(), j.compact());

    let args = |v: &[&str]| v.iter().map(|t| t.to_string()).collect::<Vec<String>>();
    let err = SearchSpec::from_args(&args(&["--shards", "0"])).unwrap_err().to_string();
    assert!(err.contains("--shards must be positive"), "{err}");
    let err = SearchSpec::from_args(&args(&["--stream-lens", "4"])).unwrap_err().to_string();
    assert!(err.contains("between 8 and 1024"), "{err}");
    let err = SearchSpec::from_args(&args(&["--bogus-flag", "1"])).unwrap_err().to_string();
    assert!(err.contains("--bogus-flag"), "{err}");
    let err = SearchSpec::from_args(&args(&["--samples", "4", "--rungs", "2"]))
        .unwrap_err()
        .to_string();
    assert!(err.contains("different samplers"), "{err}");
}
