//! Property tests for the parallel, allocation-lean simulator core
//! (PR 5): the driver-thread count, the sharded cost cache, and the
//! incremental tick costing are pure wall-clock knobs — none of them
//! may move a single bit of any reported metric, for dp and pp
//! placements, mixed QoS tiers, and randomized traces.  The aggregated
//! cache hit-rate counters must also be deterministic across thread
//! counts (deterministic in-repo harness, `util::prop`).
//!
//! Bit-identity is asserted through `ClusterReport::state_hash` — one
//! u64 over the aggregate and every per-stack report.  The
//! field-by-field oracle proving the hash stands in for full report
//! equality lives in `tests/engine_equivalence.rs`.

use artemis::cluster::{run_cluster, ClusterReport};
use artemis::config::{ArtemisConfig, ClusterConfig, ModelZoo, Placement};
use artemis::serve::{Policy, QosAssignment, RoutePolicy, Scenario, SchedulerConfig};
use artemis::sim::CacheStats;
use artemis::util::prop::check;

/// Small fast scenario on the 2-layer Transformer-base so each
/// property case simulates in milliseconds.
fn fast_scenario(sessions: usize) -> Scenario {
    let mut sc = Scenario::chat().with_sessions(sessions);
    sc.model = ModelZoo::transformer_base();
    sc
}

fn sched(batch: usize) -> SchedulerConfig {
    SchedulerConfig { max_batch: batch, policy: Policy::Fifo }
}

/// Every simulated number of two cluster reports, compared through the
/// one-u64 run digest.  `state_hash` folds the aggregate and every
/// per-stack report (all metric summaries, per-session outcomes by bit
/// pattern, the occupancy timeline, and the KV peaks), so a single
/// `assert_eq!` here is a full bit-identity claim; the field-by-field
/// oracle backing that up lives in `tests/engine_equivalence.rs`.
fn assert_bit_identical(a: &ClusterReport, b: &ClusterReport, what: &str) {
    assert_eq!(a.per_stack.len(), b.per_stack.len(), "{what}: stack count");
    assert_eq!(a.state_hash(), b.state_hash(), "{what}: state hash");
}

#[test]
fn parallel_driver_is_bit_identical_to_serial_dp() {
    let cfg = ArtemisConfig::default();
    check(5, 0x9E_0001, |g| {
        let mut sc = fast_scenario(g.usize_in(6, 14));
        if g.bool() {
            sc = sc.with_qos(QosAssignment::Mixed); // mixed tiers in flight
        }
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let stacks = [2u64, 3, 4][g.usize_in(0, 2)];
        let route = [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::KvHeadroom]
            [g.usize_in(0, 2)];
        let s = sched(g.usize_in(2, 6));
        let cl = ClusterConfig::new(stacks, Placement::DataParallel);
        let serial = run_cluster(
            &cfg,
            &sc.model,
            &trace,
            &cl.with_threads(1),
            &s,
            route,
            true,
        );
        for threads in [2usize, 4] {
            let parallel = run_cluster(
                &cfg,
                &sc.model,
                &trace,
                &cl.with_threads(threads),
                &s,
                route,
                true,
            );
            assert!(parallel.threads <= stacks as usize);
            assert_bit_identical(&serial, &parallel, &format!("dp t{threads}"));
            // The aggregated cache counters are part of the contract:
            // same lookups, same exactly-once misses, any schedule.
            assert_eq!(serial.cache, parallel.cache, "cache stats t{threads}");
        }
    });
}

#[test]
fn parallel_driver_is_bit_identical_to_serial_pp() {
    let cfg = ArtemisConfig::default();
    check(3, 0x9E_0002, |g| {
        let mut sc = fast_scenario(g.usize_in(5, 10));
        if g.bool() {
            sc = sc.with_qos(QosAssignment::Mixed);
        }
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let cl = ClusterConfig::new(2, Placement::PipelineParallel);
        let s = sched(g.usize_in(2, 5));
        let route = RoutePolicy::LeastLoaded;
        let serial = run_cluster(&cfg, &sc.model, &trace, &cl.with_threads(1), &s, route, true);
        let auto = run_cluster(&cfg, &sc.model, &trace, &cl.with_threads(0), &s, route, true);
        // A pp group is one logical replica: the pool resolves to one
        // worker, and the numbers must still match the serial path.
        assert_eq!(auto.threads, 1);
        assert_bit_identical(&serial, &auto, "pp auto");
        assert_eq!(serial.cache, auto.cache, "pp cache stats");
    });
}

#[test]
fn sharded_cache_on_off_is_bit_identical_under_the_parallel_driver() {
    let cfg = ArtemisConfig::default();
    check(3, 0x9E_0003, |g| {
        let sc = fast_scenario(g.usize_in(6, 12)).with_qos(QosAssignment::Mixed);
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let cl = ClusterConfig::new(4, Placement::DataParallel).with_threads(4);
        let s = sched(g.usize_in(2, 6));
        let hot = run_cluster(&cfg, &sc.model, &trace, &cl, &s, RoutePolicy::LeastLoaded, true);
        let cold = run_cluster(&cfg, &sc.model, &trace, &cl, &s, RoutePolicy::LeastLoaded, false);
        assert_bit_identical(&hot, &cold, "cache on/off");
        assert!(hot.cache.lookups() > 0, "cached run must consult the cache");
        assert_eq!(cold.cache, CacheStats::default(), "uncached run must count nothing");
    });
}

#[test]
fn aggregated_cache_stats_sum_replicas_and_hold_across_thread_counts() {
    let cfg = ArtemisConfig::default();
    let sc = fast_scenario(16);
    let trace = sc.generate(11);
    let s = sched(4);
    let cl = ClusterConfig::new(4, Placement::DataParallel);
    let mut seen: Option<CacheStats> = None;
    for threads in [1usize, 2, 4] {
        let r = run_cluster(
            &cfg,
            &sc.model,
            &trace,
            &cl.with_threads(threads),
            &s,
            RoutePolicy::RoundRobin,
            true,
        );
        // The run-wide line is the exact sum of the per-replica
        // counters (the satellite fix: no per-replica resets, no
        // shared-consults-only undercount).
        let summed = r
            .cache_per_stack
            .iter()
            .fold(CacheStats::default(), |acc, &x| acc.merged(x));
        assert_eq!(summed, r.cache);
        assert_eq!(r.cache_per_stack.len(), 4);
        assert!(r.cache.lookups() > 0);
        assert!(r.cache.hit_rate() > 0.5, "hit rate {}", r.cache.hit_rate());
        // And the aggregate is schedule-independent.
        match seen {
            None => seen = Some(r.cache),
            Some(prev) => assert_eq!(prev, r.cache, "threads={threads}"),
        }
    }
}

#[test]
fn thread_knob_survives_kv_pressure_and_rejections() {
    // Tiny banks + long sessions: admission control and rejections in
    // play; the parallel driver must still match the serial one.
    let mut cfg = ArtemisConfig::default();
    cfg.hbm.subarrays_per_bank = 16;
    let mut sc = Scenario::summarize().with_sessions(10);
    sc.model = ModelZoo::transformer_base();
    let trace = sc.generate(3);
    let s = sched(6);
    let cl = ClusterConfig::new(3, Placement::DataParallel);
    let route = RoutePolicy::KvHeadroom;
    let serial = run_cluster(&cfg, &sc.model, &trace, &cl.with_threads(1), &s, route, true);
    let parallel = run_cluster(&cfg, &sc.model, &trace, &cl.with_threads(3), &s, route, true);
    assert_bit_identical(&serial, &parallel, "kv pressure");
    for rep in &parallel.per_stack {
        assert!(rep.peak_kv_per_bank <= rep.kv_budget_per_bank);
    }
}
