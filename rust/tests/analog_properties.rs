//! Property tests over the analog substrate (MOMCAP + conversion).

use artemis::analog::{a_to_b, momcap_staircase, AtoBConfig, MomCap};
use artemis::util::prop::check;

#[test]
fn prop_voltage_monotone_nondecreasing() {
    check(200, 0x20, |g| {
        let c = g.f64_in(2.0, 48.0);
        let mut cap = MomCap::new(c);
        let mut last = 0.0;
        for _ in 0..60 {
            cap.accumulate(g.u64_below(129) as u32);
            assert!(cap.voltage() >= last - 1e-12);
            last = cap.voltage();
        }
    });
}

#[test]
fn prop_linear_region_readout_exact() {
    check(200, 0x21, |g| {
        let mut cap = MomCap::new(8.0);
        let window = cap.max_accumulations();
        let steps = 1 + g.u64_below(window as u64) as u32;
        for _ in 0..steps {
            cap.accumulate(g.u64_below(129) as u32);
        }
        let err = (cap.readout_units() - cap.ideal_units() as f64).abs();
        assert!(err < 0.5, "err={err} steps={steps}");
    });
}

#[test]
fn prop_noiseless_a_to_b_exact_in_window() {
    let cfg = AtoBConfig { offset_noise: 0.0, ..Default::default() };
    check(200, 0x22, |g| {
        let mut cap = MomCap::new(8.0);
        let steps = 1 + g.u64_below(20) as u32;
        for _ in 0..steps {
            cap.accumulate(g.u64_below(129) as u32);
        }
        let got = a_to_b(&cap, &cfg, None) as i64;
        let want = cap.ideal_units() as i64;
        assert!((got - want).abs() <= 1, "got={got} want={want}");
    });
}

#[test]
fn prop_capacitance_monotone_window() {
    check(50, 0x23, |g| {
        let c1 = g.f64_in(2.0, 20.0);
        let c2 = c1 + g.f64_in(1.0, 20.0);
        let w1 = MomCap::new(c1).max_accumulations();
        let w2 = MomCap::new(c2).max_accumulations();
        assert!(w2 >= w1, "c1={c1} w1={w1} c2={c2} w2={w2}");
    });
}

#[test]
fn prop_staircase_linear_count_matches_capacity() {
    check(30, 0x24, |g| {
        let c = g.f64_in(4.0, 40.0);
        let s = momcap_staircase(c, 150);
        let expect = MomCap::new(c).max_accumulations();
        let diff = s.max_linear_accumulations as i64 - expect as i64;
        let got = s.max_linear_accumulations;
        assert!(diff.abs() <= 1, "c={c} staircase={got} capacity={expect}");
    });
}

#[test]
fn prop_reset_restores_full_window() {
    check(100, 0x25, |g| {
        let mut cap = MomCap::new(8.0);
        for _ in 0..g.u64_below(40) {
            cap.accumulate(g.u64_below(129) as u32);
        }
        cap.reset();
        for _ in 0..cap.max_accumulations() {
            cap.accumulate(128);
        }
        let err = (cap.readout_units() - cap.ideal_units() as f64).abs();
        assert!(err < 0.5, "window not restored: {err}");
    });
}
