//! Property tests for the continuous-batching generation scheduler:
//! load-monotonic completion, generation-length obedience, and the
//! KV-admission capacity invariant, over randomized traces and knobs
//! (deterministic in-repo harness, `util::prop`).

use artemis::config::{ArtemisConfig, ModelZoo};
use artemis::serve::{kv_bytes, run_continuous, Policy, Scenario, SchedulerConfig};
use artemis::util::prop::check;

/// Small fast scenario: chat traffic shapes on the 2-layer
/// Transformer-base so each property case simulates in milliseconds.
fn fast_scenario(sessions: usize) -> Scenario {
    let mut sc = Scenario::chat().with_sessions(sessions);
    sc.model = ModelZoo::transformer_base();
    sc
}

#[test]
fn completion_time_is_monotone_in_arrival_load() {
    let cfg = ArtemisConfig::default();
    let sc = fast_scenario(12);
    check(6, 0x5E12_0001, |g| {
        let seed = g.u64_below(1 << 20) + 1;
        let n = g.usize_in(2, 8);
        let extra = g.usize_in(1, 4);
        let batch = g.usize_in(2, 6);
        let trace = sc.generate(seed);
        let sched = SchedulerConfig { max_batch: batch, policy: Policy::Fifo };
        let small = run_continuous(&cfg, &sc.model, &trace[..n], &sched);
        let big = run_continuous(&cfg, &sc.model, &trace[..n + extra], &sched);
        // Serving a superset of the arrivals can never finish earlier.
        assert!(
            big.makespan_ns >= small.makespan_ns - 1e-6,
            "load {} -> {}: makespan {} < {}",
            n,
            n + extra,
            big.makespan_ns,
            small.makespan_ns
        );
        assert!(big.total_tokens >= small.total_tokens);
    });
}

#[test]
fn no_session_decodes_past_its_requested_length() {
    let cfg = ArtemisConfig::default();
    check(6, 0x5E12_0002, |g| {
        let sc = fast_scenario(g.usize_in(3, 10));
        let seed = g.u64_below(1 << 20) + 1;
        let policy = if g.bool() { Policy::Fifo } else { Policy::ShortestPromptFirst };
        let sched = SchedulerConfig { max_batch: g.usize_in(1, 6), policy };
        let trace = sc.generate(seed);
        let r = run_continuous(&cfg, &sc.model, &trace, &sched);
        for s in &r.session_reports {
            assert!(s.generated <= s.gen, "session {} overshot: {s:?}", s.id);
            if !s.rejected {
                assert_eq!(s.generated, s.gen, "session {} undershot", s.id);
            } else {
                assert_eq!(s.generated, 0);
            }
        }
        let want: u64 =
            r.session_reports.iter().filter(|s| !s.rejected).map(|s| s.gen).sum();
        assert_eq!(r.total_tokens, want);
    });
}

#[test]
fn kv_admission_never_exceeds_bank_capacity() {
    check(6, 0x5E12_0003, |g| {
        let mut cfg = ArtemisConfig::default();
        // Shrink the banks so KV pressure (and rejection) is real.
        cfg.hbm.subarrays_per_bank = [8, 16, 32][g.usize_in(0, 2)];
        let mut sc = Scenario::summarize().with_sessions(g.usize_in(3, 8));
        sc.model = ModelZoo::transformer_base();
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let sched = SchedulerConfig { max_batch: g.usize_in(2, 16), policy: Policy::Fifo };
        let r = run_continuous(&cfg, &sc.model, &trace, &sched);
        assert!(
            r.peak_kv_per_bank <= r.kv_budget_per_bank,
            "KV overflow: peak {} > budget {}",
            r.peak_kv_per_bank,
            r.kv_budget_per_bank
        );
        // Rejection is exactly the could-never-fit predicate.
        let banks = cfg.hbm.banks_total().max(1);
        for s in &r.session_reports {
            let need = kv_bytes(&sc.model, s.prompt + s.gen).div_ceil(banks);
            assert_eq!(
                s.rejected,
                need > r.kv_budget_per_bank,
                "session {}: need {need} vs budget {}",
                s.id,
                r.kv_budget_per_bank
            );
        }
    });
}
