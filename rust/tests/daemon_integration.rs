//! Live-daemon integration: drive `artemis serve-daemon` over real TCP
//! through submit / status / snapshot / restore / shutdown, and assert
//! the tentpole invariant — a campaign snapshotted mid-run, the daemon
//! hard-killed, and the snapshot restored into a fresh daemon finishes
//! on the exact state hash of an uninterrupted run (and of the
//! in-process cluster driver), for both engines and both placements.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use artemis::cluster::run_cluster;
use artemis::config::{ArtemisConfig, ClusterConfig, EngineStrategy, ModelZoo, Placement};
use artemis::serve::{Policy, RoutePolicy, Scenario, SchedulerConfig, ServeSpec};
use artemis::util::cli::CliOption;
use artemis::util::json::Json;

struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    fn start() -> Self {
        let mut child = Command::new(env!("CARGO_BIN_EXE_artemis"))
            .args(["serve-daemon"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn serve-daemon");
        let stdout = child.stdout.take().expect("daemon stdout");
        let mut reader = BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).expect("daemon announce line");
        let addr = line
            .trim()
            .strip_prefix("daemon: listening on ")
            .unwrap_or_else(|| panic!("unexpected announce line: {line:?}"))
            .to_string();
        // Keep draining stdout (job completion lines) so the daemon
        // never blocks on a full pipe.
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
                sink.clear();
            }
        });
        Self { child, addr }
    }

    fn connect(&self) -> Client {
        Client::connect(&self.addr)
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.child.kill().ok();
        self.child.wait().ok();
    }
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> Self {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Self { stream, reader }
    }

    fn raw(&mut self, line: &str) -> Json {
        writeln!(self.stream, "{line}").expect("send request");
        self.stream.flush().expect("flush request");
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("read reply");
        Json::parse(reply.trim()).expect("reply must be JSON")
    }

    fn req(&mut self, body: &Json) -> Json {
        self.raw(&body.compact())
    }

    fn ok(&mut self, body: &Json) -> Json {
        let r = self.req(body);
        assert_eq!(
            r.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "request {} failed: {}",
            body.compact(),
            r.compact()
        );
        r
    }
}

/// Read a numeric field that may travel as a decimal string (the
/// daemon's u64-exact path) or a plain JSON number.
fn num_field(j: &Json, name: &str) -> u64 {
    let v = j.get(name).unwrap_or_else(|| panic!("missing '{name}': {}", j.compact()));
    match v {
        Json::Str(s) => s.parse().unwrap_or_else(|_| panic!("bad '{name}': {}", j.compact())),
        _ => v.as_u64().unwrap_or_else(|| panic!("bad '{name}': {}", j.compact())),
    }
}

fn hash_field(status: &Json) -> String {
    status
        .get("state_hash")
        .and_then(|v| v.as_str())
        .unwrap_or_else(|| panic!("no state_hash: {}", status.compact()))
        .to_string()
}

fn status(c: &mut Client, job: u64) -> Json {
    c.ok(&Json::obj(vec![("cmd", Json::Str("status".into())), ("job", Json::Num(job as f64))]))
}

fn wait_state(c: &mut Client, job: u64, want: &str) -> Json {
    for _ in 0..600 {
        let s = status(c, job);
        match s.get("state").and_then(|v| v.as_str()) {
            Some(state) if state == want => return s,
            Some("failed") => panic!("job {job} failed: {}", s.compact()),
            _ => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    panic!("job {job} never reached '{want}'");
}

/// Like [`wait_state`] but without the failed-is-fatal shortcut, for
/// tests that *expect* the failure.
fn wait_state_any(c: &mut Client, job: u64, want: &str) -> Json {
    for _ in 0..600 {
        let s = status(c, job);
        if s.get("state").and_then(|v| v.as_str()) == Some(want) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("job {job} never reached '{want}'");
}

/// The shared request: a 2-stack rr-routed chat campaign on the fast
/// 2-layer model, parameterized over engine and placement.
fn make_spec(engine: &str, placement: &str) -> ServeSpec {
    let args: Vec<String> = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "1",
        "--sessions",
        "6",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
        "--stacks",
        "2",
        "--route",
        "rr",
        "--engine",
        engine,
        "--placement",
        placement,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    ServeSpec::from_args(&args).expect("valid spec args")
}

/// The same campaign through the in-process one-shot cluster driver.
fn library_hash(engine: EngineStrategy, placement: Placement) -> String {
    let mut sc = Scenario::by_name("chat").expect("chat scenario").with_sessions(6);
    sc.model = ModelZoo::by_name("Transformer-base").expect("model");
    let trace = sc.generate(1);
    let cfg = ArtemisConfig::default();
    let cl = ClusterConfig::new(2, placement).with_engine(engine);
    let sched = SchedulerConfig { max_batch: 4, policy: Policy::Fifo };
    let r = run_cluster(&cfg, &sc.model, &trace, &cl, &sched, RoutePolicy::RoundRobin, true);
    format!("{:#018x}", r.state_hash())
}

#[test]
fn snapshot_kill_restore_lands_on_the_uninterrupted_state_hash() {
    for (engine, placement) in [("tick", "dp"), ("tick", "pp"), ("event", "dp"), ("event", "pp")] {
        let spec = make_spec(engine, placement);
        let daemon_a = Daemon::start();
        let mut ca = daemon_a.connect();

        // Uninterrupted reference run through the daemon.
        let submit = Json::obj(vec![("cmd", Json::Str("submit".into())), ("spec", spec.to_json())]);
        let r = ca.ok(&submit);
        let ref_job = num_field(&r, "job");
        let done = wait_state(&mut ca, ref_job, "done");
        let ref_hash = hash_field(&done);
        let total_units = num_field(&done, "units");
        assert!(total_units > 0, "campaign took no steps: {}", done.compact());

        // Same spec again, parked two thirds of the way in.
        let pause = (total_units * 2 / 3).max(1);
        let submit_paused = Json::obj(vec![
            ("cmd", Json::Str("submit".into())),
            ("spec", spec.to_json()),
            ("pause_after", Json::Num(pause as f64)),
        ]);
        let r = ca.ok(&submit_paused);
        let paused_job = num_field(&r, "job");
        wait_state(&mut ca, paused_job, "paused");
        if (engine, placement) == ("tick", "dp") {
            // Untraced jobs answer trace-window with one null per
            // replica — the command works, there is just no telemetry.
            let tw = Json::obj(vec![
                ("cmd", Json::Str("trace-window".into())),
                ("job", Json::Num(paused_job as f64)),
            ]);
            let w = ca.ok(&tw);
            let windows = w.get("windows").and_then(|v| v.as_arr()).expect("windows array");
            assert_eq!(windows.len(), 2, "one entry per stack: {}", w.compact());
        }
        let snap_req = Json::obj(vec![
            ("cmd", Json::Str("snapshot".into())),
            ("job", Json::Num(paused_job as f64)),
        ]);
        let snap = ca.ok(&snap_req).get("snapshot").expect("snapshot body").clone();

        // Hard-kill the daemon mid-campaign: the snapshot document is
        // all that survives.
        drop(ca);
        drop(daemon_a);

        // Fresh daemon: restore and run to completion.
        let daemon_b = Daemon::start();
        let mut cb = daemon_b.connect();
        let restore = Json::obj(vec![("cmd", Json::Str("restore".into())), ("snapshot", snap)]);
        let r = cb.ok(&restore);
        let restored_job = num_field(&r, "job");
        let done = wait_state(&mut cb, restored_job, "done");
        let restored_hash = hash_field(&done);
        assert_eq!(
            num_field(&done, "units"),
            total_units,
            "restored run took a different step count ({engine}/{placement})"
        );
        cb.ok(&Json::obj(vec![("cmd", Json::Str("shutdown".into()))]));
        drop(cb);
        drop(daemon_b);

        let lib = library_hash(
            EngineStrategy::parse_cli(engine).expect("engine"),
            Placement::parse_cli(placement).expect("placement"),
        );
        assert_eq!(
            ref_hash,
            restored_hash,
            "snapshot/kill/restore diverged from the uninterrupted run ({engine}/{placement})"
        );
        assert_eq!(
            ref_hash,
            lib,
            "daemon run diverged from the in-process driver ({engine}/{placement})"
        );
    }
}

#[test]
fn panicking_job_poisons_nothing_the_daemon_still_needs() {
    // Regression for the lock-poisoning hang: a worker that panics while
    // holding the jobs mutex used to take every later `status`, `submit`
    // and `shutdown` down with it.  The daemon must park the job in
    // `failed` (with the panic payload) and keep serving.
    let daemon = Daemon::start();
    let mut c = daemon.connect();
    let spec = make_spec("tick", "dp");

    // `inject_panic` is the daemon's test-only detonator: the worker
    // panics at the given unit count *inside* the status update, i.e.
    // while the jobs lock is held.
    let submit = Json::obj(vec![
        ("cmd", Json::Str("submit".into())),
        ("spec", spec.to_json()),
        ("inject_panic", Json::Num(1.0)),
    ]);
    let r = c.ok(&submit);
    let crashed = num_field(&r, "job");
    let s = wait_state_any(&mut c, crashed, "failed");
    let err = s.get("error").and_then(|v| v.as_str()).expect("error field");
    assert!(err.contains("panicked"), "unexpected error: {err}");

    // The same connection keeps working, and a fresh job runs to
    // completion on the recovered lock.
    let submit = Json::obj(vec![("cmd", Json::Str("submit".into())), ("spec", spec.to_json())]);
    let r = c.ok(&submit);
    let job = num_field(&r, "job");
    let done = wait_state(&mut c, job, "done");
    assert!(!hash_field(&done).is_empty());
    // Status on the crashed job still answers too.
    let s = status(&mut c, crashed);
    assert_eq!(s.get("state").and_then(|v| v.as_str()), Some("failed"));
    c.ok(&Json::obj(vec![("cmd", Json::Str("shutdown".into()))]));
}

#[test]
fn daemon_rejects_malformed_requests_and_keeps_serving() {
    let daemon = Daemon::start();
    let mut c = daemon.connect();

    let r = c.raw("this is not json");
    assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(false), "{}", r.compact());

    let r = c.req(&Json::obj(vec![("cmd", Json::Str("status".into())), ("job", Json::Num(9.0))]));
    assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(false), "{}", r.compact());
    let err = r.get("error").and_then(|v| v.as_str()).expect("error field").to_string();
    assert!(err.contains("unknown job"), "{err}");

    let bad_snap = Json::obj(vec![
        ("cmd", Json::Str("restore".into())),
        ("snapshot", Json::obj(vec![("kind", Json::Str("nope".into()))])),
    ]);
    let r = c.req(&bad_snap);
    let err = r.get("error").and_then(|v| v.as_str()).expect("error field").to_string();
    assert!(err.contains("not a serve snapshot"), "{err}");

    // A bad spec value rejects with the canonical CLI error string.
    let bad_spec = Json::obj(vec![
        ("cmd", Json::Str("submit".into())),
        ("spec", Json::obj(vec![("policy", Json::Str("sideways".into()))])),
    ]);
    let r = c.req(&bad_spec);
    let err = r.get("error").and_then(|v| v.as_str()).expect("error field").to_string();
    assert!(err.contains("unknown policy 'sideways' (fifo|spf)"), "{err}");

    let r = c.req(&Json::obj(vec![("cmd", Json::Str("explode".into()))]));
    let err = r.get("error").and_then(|v| v.as_str()).expect("error field").to_string();
    assert!(err.contains("unknown command"), "{err}");

    // The connection survived every error: a real command still works.
    c.ok(&Json::obj(vec![("cmd", Json::Str("shutdown".into()))]));
}
