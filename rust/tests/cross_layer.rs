//! Cross-layer validation: the functional runtime (AOT PJRT artifacts
//! when built with `--features pjrt` + `make artifacts`, the pure-Rust
//! reference backend otherwise) must agree bit-for-bit with the rust
//! bit-exact SC substrate.  Under PJRT this is the strongest correctness
//! statement in the repo: three independent implementations of the
//! ARTEMIS arithmetic — python/jnp oracle, Pallas kernel, rust TCU
//! streams — give identical numbers.  Under the reference backend it
//! still cross-checks two independent rust implementations (float
//! trunc-arithmetic vs TCU bit streams).

use artemis::runtime::ArtifactRegistry;
use artemis::sc::sc_multiply;
use artemis::util::XorShift64;

fn registry() -> Option<ArtifactRegistry> {
    match ArtifactRegistry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping cross-layer tests (run `make artifacts`): {e}");
            None
        }
    }
}

/// The rust reference: quantize like the python `common.py`, multiply
/// through the bit-exact TCU streams, dequantize.
fn artemis_matmul_rust(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let amax = a.iter().fold(0f32, |x, y| x.max(y.abs())).max(1e-12);
    let bmax = b.iter().fold(0f32, |x, y| x.max(y.abs())).max(1e-12);
    let (sa, sb) = (amax / 127.0, bmax / 127.0);
    let q = |x: f32, s: f32| (x / s).round_ties_even().clamp(-127.0, 127.0) as i32;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for kk in 0..k {
                let qa = q(a[i * k + kk], sa);
                let qb = q(b[kk * n + j], sb);
                let p = sc_multiply(qa.unsigned_abs(), qb.unsigned_abs()) as i64;
                acc += if (qa < 0) != (qb < 0) { -p } else { p };
            }
            out[i * n + j] = acc as f32 * sa * sb * 128.0;
        }
    }
    out
}

#[test]
fn kernel_artifacts_match_rust_bit_exact_sc() {
    let Some(mut reg) = registry() else { return };
    for (name, m, k, n) in [
        ("sc_matmul_8x16x8", 8usize, 16usize, 8usize),
        ("sc_matmul_16x64x32", 16, 64, 32),
        ("sc_matmul_32x128x64", 32, 128, 64),
    ] {
        let model = reg.load(name).expect("artifact loads");
        for seed in 0..3u64 {
            let mut rng = XorShift64::new(seed * 31 + 7);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let got = model.run_f32(&[a.clone(), b.clone()]).expect("runs");
            let want = artemis_matmul_rust(&a, &b, m, k, n);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                let tol = 1e-4 * w.abs().max(1.0);
                assert!(
                    (g - w).abs() < tol,
                    "{name} seed={seed} elem {i}: pallas {g} vs rust {w}"
                );
            }
        }
    }
}

#[test]
fn tiny_variants_rank_by_fidelity() {
    // fp32 and q8 logits should be close; q8sc close-ish; all argmax
    // mostly agreeing — the Table IV structure.
    let Some(mut reg) = registry() else { return };
    let tiny = reg.tiny_config().unwrap().clone();
    let fp32 = reg.load("tiny_fp32").expect("fp32");
    let q8 = reg.load("tiny_q8").expect("q8");

    let mut rng = XorShift64::new(0xCAFE);
    let toks: Vec<f32> = (0..tiny.batch * tiny.seq_len)
        .map(|_| rng.below(tiny.vocab as u64) as f32)
        .collect();
    let l32 = fp32.run_f32(&[toks.clone()]).unwrap();
    let l8 = q8.run_f32(&[toks.clone()]).unwrap();
    let max_diff = l32
        .iter()
        .zip(&l8)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    let scale = l32.iter().fold(0f32, |a, &b| a.max(b.abs()));
    assert!(
        max_diff < 0.35 * scale.max(1.0),
        "q8 drifted from fp32: {max_diff} (scale {scale})"
    );
}

#[test]
fn encoder_artifact_runs_at_declared_shapes() {
    let Some(mut reg) = registry() else { return };
    let enc = reg.load("encoder_q8").expect("encoder");
    let shapes = enc.input_shapes.clone();
    let mut rng = XorShift64::new(5);
    let ins: Vec<Vec<f32>> = shapes
        .iter()
        .map(|s| (0..s.iter().product::<usize>()).map(|_| rng.normal() as f32 * 0.3).collect())
        .collect();
    let out = enc.run_f32(&ins).expect("encoder runs");
    assert_eq!(out.len(), shapes[0].iter().product::<usize>());
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(reg) = registry() else { return };
    let names = reg.names();
    for required in [
        "tiny_fp32",
        "tiny_q8",
        "tiny_q8sc",
        "encoder_q8",
        "encoder_q8sc",
        "sc_matmul_8x16x8",
    ] {
        assert!(names.iter().any(|n| n == required), "missing {required}: {names:?}");
    }
    let tiny = reg.tiny_config().unwrap();
    assert_eq!(tiny.seq_len, 16);
    assert_eq!(tiny.n_classes, 2);
    assert!(tiny.batch > 0);
}
