//! Golden-vector conformance suite: replay the NumPy-generated fixtures
//! in `tests/golden/` (written by `python/tools/gen_golden.py`) against
//! the Rust implementations.
//!
//! Comparison discipline (see the generator's LIBM NOTE):
//!
//! * **Bit-exact** wherever the value chain is integer or
//!   exactly-rounded IEEE arithmetic: SC accumulators/outputs at every
//!   stream length, quantization codes, the f32 `sc_matmul` artifact,
//!   LUT grid codes.
//! * **1e-9-tight** where a value passes through libm transcendentals
//!   (exp/log): identical on the glibc CI platform, but not an IEEE
//!   guarantee, so the assert leaves ulp headroom rather than encoding
//!   a platform assumption.

use artemis::fidelity::{logit_rms_error, CODE_TO_LOGIT, MARGIN_MEAN, MARGIN_STD};
use artemis::runtime::ArtifactRegistry;
use artemis::sc::{quant_scale_f64, quantize_f64, sc_matmul_len, FidelityPolicy};
use artemis::util::json::Json;

fn fixture(name: &str) -> Json {
    let path = format!("{}/tests/golden/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing fixture {path}: {e} (run python/tools/gen_golden.py)"));
    Json::parse(&text).unwrap_or_else(|e| panic!("bad fixture {path}: {e}"))
}

fn f64s(j: &Json, key: &str) -> Vec<f64> {
    j.get(key)
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("fixture missing array '{key}'"))
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

fn usize_of(j: &Json, key: &str) -> usize {
    j.get(key).and_then(Json::as_u64).unwrap_or_else(|| panic!("missing '{key}'")) as usize
}

#[test]
fn sc_matmul_len_fixtures_replay_bit_exactly() {
    let j = fixture("sc_matmul_len.json");
    let (m, k, n) = (usize_of(&j, "m"), usize_of(&j, "k"), usize_of(&j, "n"));
    let a = f64s(&j, "a");
    let b = f64s(&j, "b");
    assert_eq!(quant_scale_f64(&a), j.get("s_a").unwrap().as_f64().unwrap());
    assert_eq!(quant_scale_f64(&b), j.get("s_b").unwrap().as_f64().unwrap());
    let cases = j.get("cases").and_then(Json::as_arr).unwrap();
    assert_eq!(cases.len(), 5, "expected stream lengths 16..256");
    let mut prev_rms = f64::INFINITY;
    for case in cases {
        let len = case.get("stream_len").and_then(Json::as_u64).unwrap() as u32;
        let want_acc = f64s(case, "acc");
        let want_out = f64s(case, "out");
        let (acc, out, _, _) = sc_matmul_len(&a, &b, m, k, n, len);
        // Pure integer + dyadic arithmetic on both sides: bit-exact.
        for (i, (&g, &w)) in acc.iter().zip(&want_acc).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "len={len} acc[{i}]: {g} vs {w}");
        }
        for (i, (&g, &w)) in out.iter().zip(&want_out).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "len={len} out[{i}]: {g} vs {w}");
        }
        // And the acceptance trend: dequantized error vs the f64 matmul
        // strictly shrinks as the stream doubles.
        let mut se = 0.0;
        for i in 0..m {
            for jj in 0..n {
                let exact: f64 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + jj]).sum();
                let e = out[i * n + jj] - exact;
                se += e * e;
            }
        }
        let rms = (se / (m * n) as f64).sqrt();
        assert!(rms < prev_rms, "len={len}: rms {rms} !< {prev_rms}");
        prev_rms = rms;
    }
}

#[test]
fn reference_backend_sc_matmul_matches_f32_fixture_bit_exactly() {
    let j = fixture("ref_sc_matmul.json");
    let artifact = j.get("artifact").unwrap().as_str().unwrap();
    let a: Vec<f32> = f64s(&j, "a").iter().map(|&v| v as f32).collect();
    let b: Vec<f32> = f64s(&j, "b").iter().map(|&v| v as f32).collect();
    let want: Vec<f32> = f64s(&j, "out").iter().map(|&v| v as f32).collect();
    let mut reg = ArtifactRegistry::builtin_reference();
    let model = reg.load(artifact).unwrap();
    let got = model.run_f32(&[a, b]).unwrap();
    assert_eq!(got.len(), want.len());
    // Quantize → integer trunc-SC accumulate → dequantize is all
    // exactly-rounded f32 arithmetic: bit-exact against the NumPy
    // float32 mirror.
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "out[{i}]: {g} vs {w}");
    }
}

#[test]
fn nsc_softmax_fixture_codes_bit_exact_outputs_tight() {
    let j = fixture("nsc_softmax.json");
    let width = usize_of(&j, "width");
    for (r, row) in j.get("rows").and_then(Json::as_arr).unwrap().iter().enumerate() {
        let input = f64s(row, "input");
        let want = f64s(row, "output");
        assert_eq!(input.len(), width);
        let got = artemis::nsc::nsc_softmax(&input);
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-9, "row {r} [{i}]: {g} vs {w}");
        }
        // The exp-LUT quantization grid itself is arithmetic-only:
        // recompute the codes and compare bit-exactly.
        let want_codes: Vec<u64> = row
            .get("exp_codes")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap())
            .collect();
        let ymax = input.iter().cloned().fold(f64::MIN, f64::max);
        for (i, (&v, &wc)) in input.iter().zip(&want_codes).enumerate() {
            let xc = (v - ymax).clamp(-16.0, 0.0);
            let code = ((xc + 16.0) * (255.0 / 16.0)).round() as u64;
            assert_eq!(code, wc, "row {r} code[{i}]");
        }
    }
}

#[test]
fn q8_roundtrip_fixture_is_bit_exact() {
    let j = fixture("q8_roundtrip.json");
    let x = f64s(&j, "x");
    let want_scale = j.get("scale").unwrap().as_f64().unwrap();
    let scale = quant_scale_f64(&x);
    assert_eq!(scale.to_bits(), want_scale.to_bits());
    let codes = quantize_f64(&x, scale);
    let want_codes: Vec<i64> = j
        .get("codes")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i64)
        .collect();
    assert_eq!(codes.len(), want_codes.len());
    for (i, (&g, &w)) in codes.iter().zip(&want_codes).enumerate() {
        assert_eq!(g as i64, w, "code[{i}]");
    }
    let want_deq = f64s(&j, "dequant");
    for (i, (&q, &w)) in codes.iter().zip(&want_deq).enumerate() {
        let deq = q as f64 * scale;
        assert_eq!(deq.to_bits(), w.to_bits(), "dequant[{i}]");
        // Round-trip error bounded by half a step.
        assert!((deq - x[i]).abs() <= scale / 2.0 + 1e-12);
    }
}

#[test]
fn tiny_classifier_q8sc_logits_match_numpy_mirror() {
    let j = fixture("tiny_logits.json");
    let artifact = j.get("artifact").unwrap().as_str().unwrap();
    let cfgj = j.get("config").unwrap();
    // The fixture is generated at the built-in geometry; if that ever
    // changes, regenerate rather than silently comparing mismatches.
    let mut reg = ArtifactRegistry::builtin_reference();
    let tiny = reg.tiny_config().unwrap().clone();
    assert_eq!(usize_of(cfgj, "d_model"), tiny.d_model, "fixture/config drift");
    assert_eq!(usize_of(cfgj, "seq_len"), tiny.seq_len);
    assert_eq!(usize_of(cfgj, "batch"), tiny.batch);

    let tokens: Vec<f32> = f64s(&j, "tokens").iter().map(|&v| v as f32).collect();
    let want_logits: Vec<f32> = f64s(&j, "logits").iter().map(|&v| v as f32).collect();
    let want_preds: Vec<u64> = j
        .get("predictions")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap())
        .collect();

    let model = reg.load(artifact).unwrap();
    let got = model.run_f32(&[tokens]).unwrap();
    assert_eq!(got.len(), want_logits.len());
    // The forward chain crosses libm (weight-gen Box–Muller, the f32
    // calibration softmax, the f64 LUT softmax): tight rather than
    // bit-exact, plus exact predicted classes.
    for (i, (&g, &w)) in got.iter().zip(&want_logits).enumerate() {
        assert!((g - w).abs() <= 1e-4, "logit[{i}]: {g} vs {w}");
    }
    for (row, &want) in want_preds.iter().enumerate() {
        let (l0, l1) = (got[row * 2], got[row * 2 + 1]);
        let pred = u64::from(l1 > l0);
        assert_eq!(pred, want, "prediction[{row}]");
    }
}

#[test]
fn fidelity_estimator_constants_and_curve_match_numpy_reference() {
    let j = fixture("fidelity_model.json");
    // The estimator's pinned constants must equal what the generator
    // measured (drift in either side fails here or in CI's fixture
    // diff).
    assert!((MARGIN_MEAN - j.get("margin_mean").unwrap().as_f64().unwrap()).abs() < 1e-9);
    assert!((MARGIN_STD - j.get("margin_std").unwrap().as_f64().unwrap()).abs() < 1e-9);
    assert!((CODE_TO_LOGIT - j.get("code_to_logit").unwrap().as_f64().unwrap()).abs() < 1e-12);

    // The sampled logit RMS strictly decreases with stream length and
    // the analytic estimator tracks it within its documented band.
    let dims = j.get("dims").unwrap();
    let model = artemis::config::TransformerModel {
        name: "tiny".into(),
        arch: artemis::config::Arch::EncoderOnly,
        params_m: 0.1,
        layers: usize_of(dims, "layers") as u32,
        seq_len: usize_of(dims, "seq_len") as u32,
        heads: 4,
        d_model: usize_of(dims, "d_model") as u32,
        d_ff: usize_of(dims, "d_ff") as u32,
        gelu: false,
    };
    let sampled = j.get("sampled_logit_rms").unwrap();
    let mut prev = f64::INFINITY;
    for len in [16u32, 32, 64, 128, 256] {
        let s = sampled.get(&len.to_string()).unwrap().as_f64().unwrap();
        assert!(s < prev, "sampled rms not decreasing at {len}");
        prev = s;
        let est = logit_rms_error(&model, &FidelityPolicy::Uniform(len), 0.0);
        let ratio = est / s;
        assert!(
            (0.3..=3.0).contains(&ratio),
            "len={len}: estimator {est:.5} vs sampled {s:.5} (x{ratio:.2})"
        );
    }
}
