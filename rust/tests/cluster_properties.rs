//! Property tests for the multi-stack cluster driver: conservation
//! (every session served exactly once, by exactly one replica),
//! KV-budget safety per stack, scale-out monotonicity, and the
//! cost-cache bit-identicality invariant — over randomized traces,
//! stack counts and routing policies (deterministic in-repo harness,
//! `util::prop`).

use artemis::cluster::run_cluster;
use artemis::config::{ArtemisConfig, ClusterConfig, ModelZoo, Placement};
use artemis::serve::{Policy, RoutePolicy, Scenario, SchedulerConfig};
use artemis::util::prop::check;

/// Small fast scenario: chat traffic shapes on the 2-layer
/// Transformer-base so each property case simulates in milliseconds.
fn fast_scenario(sessions: usize) -> Scenario {
    let mut sc = Scenario::chat().with_sessions(sessions);
    sc.model = ModelZoo::transformer_base();
    sc
}

fn any_route(pick: usize) -> RoutePolicy {
    [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::KvHeadroom][pick % 3]
}

#[test]
fn every_session_is_served_once_by_one_replica() {
    let cfg = ArtemisConfig::default();
    check(6, 0xC1_0001, |g| {
        let sc = fast_scenario(g.usize_in(4, 12));
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let stacks = [1u64, 2, 3, 4][g.usize_in(0, 3)];
        let route = any_route(g.usize_in(0, 2));
        let sched = SchedulerConfig { max_batch: g.usize_in(2, 6), policy: Policy::Fifo };
        let cl = ClusterConfig::new(stacks, Placement::DataParallel);
        let r = run_cluster(&cfg, &sc.model, &trace, &cl, &sched, route, true);
        // Conservation: the union of per-stack sessions is the trace.
        let per_stack_total: usize = r.per_stack.iter().map(|s| s.sessions).sum();
        assert_eq!(per_stack_total, trace.len());
        assert_eq!(r.aggregate.sessions, trace.len());
        let mut ids: Vec<u64> = r
            .per_stack
            .iter()
            .flat_map(|s| s.session_reports.iter().map(|x| x.id))
            .collect();
        ids.sort_unstable();
        let mut want: Vec<u64> = trace.iter().map(|s| s.id).collect();
        want.sort_unstable();
        assert_eq!(ids, want, "stacks={stacks} route={route}");
        // Everyone fully served on the default-capacity machine.
        assert_eq!(r.aggregate.rejected, 0);
        let tokens: u64 = trace.iter().map(|s| s.gen).sum();
        assert_eq!(r.aggregate.total_tokens, tokens);
    });
}

#[test]
fn per_stack_kv_never_exceeds_budget() {
    check(6, 0xC1_0002, |g| {
        let mut cfg = ArtemisConfig::default();
        // Shrink the banks so KV pressure (and rejection) is real.
        cfg.hbm.subarrays_per_bank = [8, 16, 32][g.usize_in(0, 2)];
        let mut sc = Scenario::summarize().with_sessions(g.usize_in(3, 8));
        sc.model = ModelZoo::transformer_base();
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let stacks = [2u64, 3][g.usize_in(0, 1)];
        let route = any_route(g.usize_in(0, 2));
        let sched = SchedulerConfig { max_batch: g.usize_in(2, 8), policy: Policy::Fifo };
        let cl = ClusterConfig::new(stacks, Placement::DataParallel);
        let r = run_cluster(&cfg, &sc.model, &trace, &cl, &sched, route, true);
        for s in &r.per_stack {
            assert!(
                s.peak_kv_per_bank <= s.kv_budget_per_bank,
                "KV overflow on {}: peak {} > budget {}",
                s.scheme,
                s.peak_kv_per_bank,
                s.kv_budget_per_bank
            );
        }
        for s in &r.aggregate.session_reports {
            assert!(s.rejected || s.generated == s.gen, "session {} half-served", s.id);
        }
    });
}

#[test]
fn adding_stacks_never_hurts_aggregate_throughput() {
    let cfg = ArtemisConfig::default();
    check(4, 0xC1_0003, |g| {
        let sc = fast_scenario(g.usize_in(8, 14));
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let sched = SchedulerConfig { max_batch: g.usize_in(2, 4), policy: Policy::Fifo };
        let route = RoutePolicy::LeastLoaded;
        let mut last = 0.0f64;
        for stacks in [1u64, 2, 4] {
            let cl = ClusterConfig::new(stacks, Placement::DataParallel);
            let r = run_cluster(&cfg, &sc.model, &trace, &cl, &sched, route, true);
            let tps = r.tokens_per_s();
            // Splitting a backlogged trace over more replicas can only
            // shrink the makespan (tiny slack for the final stack whose
            // last session dominates either way).
            assert!(
                tps >= last * 0.999,
                "stacks={stacks}: {tps} tok/s < previous {last}"
            );
            last = tps;
        }
    });
}

#[test]
fn cost_cache_never_changes_a_metric_bit() {
    let cfg = ArtemisConfig::default();
    check(3, 0xC1_0004, |g| {
        let sc = fast_scenario(g.usize_in(4, 10));
        let trace = sc.generate(g.u64_below(1 << 20) + 1);
        let stacks = [1u64, 2][g.usize_in(0, 1)];
        let placement =
            if g.bool() { Placement::DataParallel } else { Placement::PipelineParallel };
        let route = any_route(g.usize_in(0, 2));
        let sched = SchedulerConfig { max_batch: g.usize_in(2, 6), policy: Policy::Fifo };
        let cl = ClusterConfig::new(stacks, placement);
        let hot = run_cluster(&cfg, &sc.model, &trace, &cl, &sched, route, true);
        let cold = run_cluster(&cfg, &sc.model, &trace, &cl, &sched, route, false);
        // One u64 covers the aggregate and every per-stack report
        // (field-by-field oracle: tests/engine_equivalence.rs).
        assert_eq!(hot.state_hash(), cold.state_hash(), "cache on/off moved a bit");
        assert!(hot.cache.lookups() > 0);
        assert_eq!(cold.cache.lookups(), 0);
    });
}

#[test]
fn pp_groups_scale_decode_throughput() {
    // The bottleneck stage shrinks as the pipeline deepens: pp x2 and
    // pp x4 must both beat the single stack on the same trace.
    let cfg = ArtemisConfig::default();
    let sc = fast_scenario(10);
    let trace = sc.generate(7);
    let sched = SchedulerConfig { max_batch: 4, policy: Policy::Fifo };
    let route = RoutePolicy::LeastLoaded;
    let single = run_cluster(
        &cfg,
        &sc.model,
        &trace,
        &ClusterConfig::new(1, Placement::DataParallel),
        &sched,
        route,
        true,
    );
    let pp2 = run_cluster(
        &cfg,
        &sc.model,
        &trace,
        &ClusterConfig::new(2, Placement::PipelineParallel),
        &sched,
        route,
        true,
    );
    assert_eq!(single.aggregate.total_tokens, pp2.aggregate.total_tokens);
    assert!(
        pp2.tokens_per_s() > single.tokens_per_s(),
        "pp x2 {} vs single {}",
        pp2.tokens_per_s(),
        single.tokens_per_s()
    );
}
