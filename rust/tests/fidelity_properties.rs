//! Property tests for the fidelity engine (ISSUE 4 satellite):
//!
//! * estimated accuracy is monotone non-decreasing in stream length;
//! * the σ=0 analog noise path reproduces the exact MOMCAP
//!   accumulation bit-identically;
//! * gold-tier serving never reports a lower accuracy percentile than
//!   bronze on the same seeded trace;
//! * the memoized cost cache stays bit-identical on/off with fidelity
//!   policies (mixed QoS tiers) active.

use artemis::analog::{AccumNoise, MomCap, SeededMomCap};
use artemis::cluster::run_cluster;
use artemis::config::{ArtemisConfig, ClusterConfig, ModelZoo, Placement};
use artemis::fidelity::{estimate, QosTier, ServeFidelity};
use artemis::sc::FidelityPolicy;
use artemis::serve::{
    run_continuous, Policy, QosAssignment, RoutePolicy, Scenario, SchedulerConfig,
};
use artemis::util::prop::check;

/// Small fast scenario on the 2-layer Transformer-base.
fn fast_scenario(sessions: usize) -> Scenario {
    let mut sc = Scenario::chat().with_sessions(sessions);
    sc.model = ModelZoo::transformer_base();
    sc
}

#[test]
fn estimated_accuracy_is_monotone_in_stream_length() {
    // Across models, noise levels, and randomized adjacent length
    // pairs: longer streams never estimate worse accuracy.
    let models = [ModelZoo::transformer_base(), ModelZoo::opt_350(), ModelZoo::bert_base()];
    check(24, 0xF1DE_0001, |g| {
        let model = &models[g.usize_in(0, 2)];
        let sigma = [0.0, 1.0, 4.0][g.usize_in(0, 2)];
        let lo = 8u32 << g.usize_in(0, 5); // 8..=256
        let hi = lo * 2;
        let a_lo = estimate(model, &FidelityPolicy::Uniform(lo), sigma).accuracy;
        let a_hi = estimate(model, &FidelityPolicy::Uniform(hi), sigma).accuracy;
        assert!(
            a_hi >= a_lo,
            "{}: accuracy({hi}) = {a_hi} < accuracy({lo}) = {a_lo} at sigma {sigma}",
            model.name
        );
    });
}

#[test]
fn zero_sigma_noise_path_is_bit_identical_to_exact_accumulation() {
    check(12, 0xF1DE_0002, |g| {
        let cap_pf = [4.0, 8.0, 16.0][g.usize_in(0, 2)];
        let seed = g.u64_below(1 << 32);
        let mut exact = MomCap::new(cap_pf);
        let mut seeded = SeededMomCap::new(cap_pf, AccumNoise::NONE, seed);
        for _ in 0..200 {
            let p = g.u64_below(129) as u32;
            let dv_exact = exact.accumulate(p);
            let dv_seeded = seeded.accumulate(p);
            assert_eq!(dv_exact.to_bits(), dv_seeded.to_bits());
            assert_eq!(exact.voltage().to_bits(), seeded.voltage().to_bits());
        }
        assert_eq!(exact.ideal_units(), seeded.ideal_units());
        // The same machinery with any mechanism on diverges (sanity
        // that the bit-identity above is not vacuous).
        let mut noisy = SeededMomCap::new(cap_pf, AccumNoise::charge_injection(4.0), seed);
        for _ in 0..40 {
            noisy.accumulate(100);
            exact.accumulate(100);
        }
        assert_ne!(noisy.voltage().to_bits(), exact.voltage().to_bits());
    });
}

#[test]
fn gold_accuracy_percentiles_never_below_bronze_on_same_trace() {
    let cfg = ArtemisConfig::default();
    check(6, 0xF1DE_0003, |g| {
        let seed = g.u64_below(1 << 20) + 1;
        let n = g.usize_in(3, 8);
        let batch = g.usize_in(2, 5);
        let sched = SchedulerConfig { max_batch: batch, policy: Policy::Fifo };
        let run = |tier: QosTier| {
            let sc = fast_scenario(n).with_qos(QosAssignment::Uniform(tier));
            let trace = sc.generate(seed);
            run_continuous(&cfg, &sc.model, &trace, &sched)
        };
        let gold = run(QosTier::Gold);
        let bronze = run(QosTier::Bronze);
        assert_eq!(gold.total_tokens, bronze.total_tokens);
        // Every accuracy percentile: gold >= bronze (strict on served
        // traces since the tier estimates are strictly ordered).
        assert!(gold.accuracy.p50 >= bronze.accuracy.p50);
        assert!(gold.accuracy.p10 >= bronze.accuracy.p10);
        assert!(gold.accuracy.min >= bronze.accuracy.min);
        assert!(gold.accuracy.mean >= bronze.accuracy.mean);
        if gold.rejected == 0 && gold.accuracy.count > 0 {
            assert!(gold.accuracy.min > bronze.accuracy.min);
        }
        // And the bronze trade is real: faster makespan, lower energy.
        assert!(bronze.makespan_ns < gold.makespan_ns);
        assert!(bronze.sim_energy_pj < gold.sim_energy_pj);
    });
}

#[test]
fn cost_cache_stays_bit_identical_with_fidelity_policies_active() {
    // Mixed QoS tiers on a 2-stack cluster: memoization must not move
    // a single bit of any metric even though tick costs are scaled by
    // per-batch fidelity factors.
    let cfg = ArtemisConfig::default();
    let model = ModelZoo::transformer_base();
    let sc = fast_scenario(14).with_qos(QosAssignment::Mixed);
    let trace = sc.generate(9);
    let cl = ClusterConfig::new(2, Placement::DataParallel);
    let sched = SchedulerConfig { max_batch: 4, policy: Policy::Fifo };
    let hot = run_cluster(&cfg, &model, &trace, &cl, &sched, RoutePolicy::LeastLoaded, true);
    let cold = run_cluster(&cfg, &model, &trace, &cl, &sched, RoutePolicy::LeastLoaded, false);
    let (h, c) = (&hot.aggregate, &cold.aggregate);
    assert_eq!(h.makespan_ns.to_bits(), c.makespan_ns.to_bits());
    assert_eq!(h.sim_energy_pj.to_bits(), c.sim_energy_pj.to_bits());
    assert_eq!(h.per_token.mean.to_bits(), c.per_token.mean.to_bits());
    assert_eq!(h.ttft.p99.to_bits(), c.ttft.p99.to_bits());
    assert_eq!(h.accuracy.p50.to_bits(), c.accuracy.p50.to_bits());
    assert_eq!(h.accuracy.p10.to_bits(), c.accuracy.p10.to_bits());
    assert_eq!(h.total_tokens, c.total_tokens);
    assert_eq!(h.ticks, c.ticks);
    assert!(hot.cache.hit_rate() > 0.5, "hit rate {}", hot.cache.hit_rate());
    // The mixed trace exercised more than one tier.
    let tiers: std::collections::HashSet<_> = h.session_reports.iter().map(|s| s.tier).collect();
    assert!(tiers.len() >= 2, "trace did not mix tiers");
}

#[test]
fn gold_only_serving_is_bit_identical_to_the_pre_qos_scheduler_shape() {
    // The gold tier's factors are exactly 1.0, so a gold-only run must
    // produce the same clock arithmetic as a run whose factors were
    // never applied.  Cross-check through the ServeFidelity table
    // itself: time/energy factors exactly 1.0 and every session report
    // tagged gold at the gold estimate.
    let cfg = ArtemisConfig::default();
    let sc = fast_scenario(6);
    let trace = sc.generate(2);
    let r = run_continuous(&cfg, &sc.model, &trace, &SchedulerConfig::default());
    let fid = ServeFidelity::for_model(&cfg.fidelity, &sc.model);
    assert_eq!(fid.time(QosTier::Gold).to_bits(), 1.0f64.to_bits());
    assert_eq!(fid.energy(QosTier::Gold).to_bits(), 1.0f64.to_bits());
    for s in &r.session_reports {
        assert_eq!(s.tier, QosTier::Gold);
        assert_eq!(s.est_accuracy.to_bits(), fid.accuracy(QosTier::Gold).to_bits());
    }
    assert_eq!(r.accuracy.count, 6);
}
