//! CLI smoke tests: the `artemis` binary's core commands must exit 0 and
//! print the paper's headline numbers (34 ns multiply, 64 MACs / 48 ns).

use std::process::Command;

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_artemis"))
        .args(args)
        .output()
        .expect("spawn artemis binary");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// The `state-hash 0x...` lines serve-gen prints: one u64 per report,
/// covering the run's whole simulated outcome.
fn state_hashes(out: &str) -> Vec<&str> {
    out.lines().filter(|l| l.trim_start().starts_with("state-hash ")).collect()
}

#[test]
fn help_exits_zero_and_lists_commands() {
    let (ok, stdout, stderr) = run(&["help"]);
    assert!(ok, "help failed: {stderr}");
    let cmds = [
        "fig2",
        "fig7",
        "tab4",
        "micro",
        "simulate",
        "serve",
        "serve-gen",
        "csv",
        "cluster-scale",
        "bench-serve",
        "bench-scale",
        "fidelity-sweep",
        "trace-report",
        "serve-daemon",
        "--placement dp|pp",
        "--qos gold|silver|bronze|mix",
        "--engine tick|event",
        "--trace FILE",
        "--spec FILE",
        "long_itl",
    ];
    for cmd in cmds {
        assert!(stdout.contains(cmd), "help missing '{cmd}':\n{stdout}");
    }
}

#[test]
fn no_args_defaults_to_help() {
    let (ok, stdout, _) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE: artemis"));
}

#[test]
fn micro_prints_headline_numbers() {
    let (ok, stdout, stderr) = run(&["micro"]);
    assert!(ok, "micro failed: {stderr}");
    // 34 ns stochastic multiply (2 MOCs x 17 ns)...
    assert!(stdout.contains("34"), "missing 34ns multiply:\n{stdout}");
    // ... and 64 MACs per 48 ns subarray step.
    assert!(stdout.contains("64 in 48ns"), "missing 64 MACs/48ns:\n{stdout}");
    // The DRISA comparison (Section I: ~47x).
    assert!(stdout.contains("47"), "missing 47x DRISA factor:\n{stdout}");
}

#[test]
fn fig7_prints_momcap_staircases() {
    let (ok, stdout, stderr) = run(&["fig7"]);
    assert!(ok, "fig7 failed: {stderr}");
    assert!(stdout.contains("Fig. 7"), "missing title:\n{stdout}");
    // The 8 pF design point supports exactly 20 linear accumulations.
    let eight_pf = stdout
        .lines()
        .find(|l| l.trim_start().starts_with('8'))
        .unwrap_or_else(|| panic!("no 8 pF row:\n{stdout}"));
    assert!(eight_pf.contains("20"), "8 pF row should show 20 steps: {eight_pf}");
}

#[test]
fn serve_gen_prints_percentiles_and_is_deterministic() {
    // Small seeded trace so the debug binary finishes quickly.
    let args =
        ["serve-gen", "--scenario", "chat", "--seed", "1", "--sessions", "6", "--batch", "4"];
    let (ok, out1, stderr) = run(&args);
    assert!(ok, "serve-gen failed: {stderr}");
    for needle in ["p99", "ttft", "per-token", "tokens/s", "continuous(fifo b4)", "static(b4)"] {
        assert!(out1.contains(needle), "missing '{needle}':\n{out1}");
    }
    // Simulated clock + seeded loadgen: byte-identical across runs.
    let (ok2, out2, _) = run(&args);
    assert!(ok2);
    assert_eq!(out1, out2, "serve-gen must be deterministic for a fixed seed");
}

#[test]
fn serve_gen_cluster_prints_aggregate_and_cache_stats() {
    // Small cluster run on the fast 2-layer model (debug binary).
    let args = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "1",
        "--sessions",
        "8",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
        "--stacks",
        "2",
        "--placement",
        "dp",
        "--route",
        "rr",
    ];
    let (ok, out1, stderr) = run(&args);
    assert!(ok, "cluster serve-gen failed: {stderr}");
    for needle in [
        "serve-gen cluster",
        "2 stacks dp",
        "route rr",
        "stack0(",
        "stack1(",
        "cluster(",
        "aggregate:",
        "tokens/s",
        "cost-cache: on",
        "hit-rate",
    ] {
        assert!(out1.contains(needle), "missing '{needle}':\n{out1}");
    }
    // Deterministic for a fixed seed, like the single-machine path.
    let (ok2, out2, _) = run(&args);
    assert!(ok2);
    assert_eq!(out1, out2, "cluster serve-gen must be deterministic");
}

#[test]
fn serve_gen_cluster_logs_one_accurate_aggregated_hit_rate() {
    // The cost-cache line aggregates every replica's lookup counters;
    // the printed percentage must match the printed hits/misses
    // exactly (regression test for the per-replica/reset stats bug).
    let args = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "2",
        "--sessions",
        "10",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
        "--stacks",
        "3",
    ];
    let (ok, out, stderr) = run(&args);
    assert!(ok, "cluster serve-gen failed: {stderr}");
    let line = out
        .lines()
        .find(|l| l.starts_with("cost-cache: on"))
        .unwrap_or_else(|| panic!("no cost-cache line:\n{out}"));
    let grab = |tag: &str| -> f64 {
        let rest = &line[line.find(tag).unwrap_or_else(|| panic!("no '{tag}': {line}"))
            + tag.len()..];
        rest.trim_start()
            .split(|c: char| !(c.is_ascii_digit() || c == '.'))
            .next()
            .unwrap()
            .parse()
            .unwrap_or_else(|e| panic!("bad number after '{tag}' ({e}): {line}"))
    };
    let (hits, misses, rate) = (grab("hits"), grab("misses"), grab("hit-rate"));
    assert!(hits + misses > 0.0, "cache never consulted: {line}");
    let expect = 100.0 * hits / (hits + misses);
    assert!(
        (rate - expect).abs() < 0.05 + 1e-9,
        "logged hit-rate {rate}% vs recomputed {expect:.3}% ({line})"
    );
    // A multi-replica chat trace memoizes most lookups.
    assert!(expect > 50.0, "implausibly low aggregated hit rate: {line}");
}

#[test]
fn serve_gen_threads_flag_never_moves_a_number() {
    // --threads is a wall-clock knob only: serial and parallel drivers
    // must print byte-identical reports (the perf PR's core invariant).
    let base = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "1",
        "--sessions",
        "8",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
        "--stacks",
        "2",
    ];
    let mut serial = base.to_vec();
    serial.extend(["--threads", "1"]);
    let mut parallel = base.to_vec();
    parallel.extend(["--threads", "2"]);
    let (ok1, out1, stderr) = run(&serial);
    assert!(ok1, "serial serve-gen failed: {stderr}");
    let (ok2, out2, stderr) = run(&parallel);
    assert!(ok2, "parallel serve-gen failed: {stderr}");
    // The one-u64 digest is the invariant the suite leans on...
    assert_eq!(
        state_hashes(&out1),
        state_hashes(&out2),
        "--threads 1 vs --threads 2 state hash drifted"
    );
    // ... and byte-identical output is the CLI-level oracle backing it
    // (nothing else in the output may drift either).
    assert_eq!(out1, out2, "--threads 1 vs --threads 2 output drifted");
    // --threads alone (without --stacks) selects cluster mode too.
    let (ok3, out3, stderr) = run(&["serve-gen", "--sessions", "4", "--model",
        "Transformer-base", "--threads", "1"]);
    assert!(ok3, "threads-only serve-gen failed: {stderr}");
    assert!(out3.contains("serve-gen cluster"), "{out3}");
}

#[test]
fn serve_gen_rejects_bad_cluster_flags() {
    let (ok, _, stderr) = run(&["serve-gen", "--stacks", "2", "--placement", "sideways"]);
    assert!(!ok);
    assert!(stderr.contains("unknown placement"), "{stderr}");
    let (ok, _, stderr) = run(&["serve-gen", "--stacks", "2", "--route", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("unknown route policy"), "{stderr}");
    let (ok, _, stderr) = run(&["serve-gen", "--stacks", "0"]);
    assert!(!ok);
    assert!(stderr.contains("--stacks must be positive"), "{stderr}");
}

#[test]
fn serve_gen_zero_sessions_prints_empty_trace_report() {
    // `--sessions 0` must cleanly report an empty trace, exit 0 —
    // single-machine and cluster mode, either engine.
    let (ok, stdout, stderr) = run(&["serve-gen", "--sessions", "0"]);
    assert!(ok, "empty serve-gen failed: {stderr}");
    assert!(stdout.contains("empty trace (0 sessions)"), "{stdout}");
    let (ok, stdout, stderr) = run(&["serve-gen", "--sessions", "0", "--stacks", "4"]);
    assert!(ok, "empty cluster serve-gen failed: {stderr}");
    assert!(stdout.contains("empty trace (0 sessions)"), "{stdout}");
    let (ok, stdout, stderr) = run(&["serve-gen", "--sessions", "0", "--engine", "event"]);
    assert!(ok, "empty event-engine serve-gen failed: {stderr}");
    assert!(stdout.contains("empty trace (0 sessions)"), "{stdout}");
    let (ok, stdout, stderr) =
        run(&["serve-gen", "--sessions", "0", "--stacks", "2", "--engine", "event"]);
    assert!(ok, "empty event-engine cluster serve-gen failed: {stderr}");
    assert!(stdout.contains("empty trace (0 sessions)"), "{stdout}");
}

#[test]
fn serve_gen_engine_flag_never_moves_a_number() {
    // Single machine: apart from the `##` header (which echoes the
    // engine), every line — all percentiles, the comparison table, and
    // the state-hash digests — must be byte-identical across engines.
    let base = [
        "serve-gen",
        "--scenario",
        "burst",
        "--seed",
        "3",
        "--sessions",
        "8",
        "--batch",
        "3",
        "--model",
        "Transformer-base",
    ];
    let mut tick = base.to_vec();
    tick.extend(["--engine", "tick"]);
    let mut event = base.to_vec();
    event.extend(["--engine", "event"]);
    let (ok1, out1, stderr) = run(&tick);
    assert!(ok1, "tick serve-gen failed: {stderr}");
    let (ok2, out2, stderr) = run(&event);
    assert!(ok2, "event serve-gen failed: {stderr}");
    assert!(out1.contains("engine tick") && out2.contains("engine event"));
    let hashes1 = state_hashes(&out1);
    assert!(!hashes1.is_empty(), "no state-hash lines:\n{out1}");
    assert_eq!(hashes1, state_hashes(&out2), "engine moved a state hash");
    let body = |o: &str| -> Vec<String> {
        o.lines().filter(|l| !l.starts_with("##")).map(str::to_owned).collect()
    };
    assert_eq!(body(&out1), body(&out2), "engine moved a printed number");

    // Cluster mode: the cost-cache line legitimately differs (the
    // event engine takes fewer lookups), so the equality claim is the
    // state hash plus the aggregate metrics line.
    let cbase = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "1",
        "--sessions",
        "8",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
        "--stacks",
        "2",
    ];
    let mut ctick = cbase.to_vec();
    ctick.extend(["--engine", "tick"]);
    let mut cevent = cbase.to_vec();
    cevent.extend(["--engine", "event"]);
    let (ok1, out1, stderr) = run(&ctick);
    assert!(ok1, "tick cluster failed: {stderr}");
    let (ok2, out2, stderr) = run(&cevent);
    assert!(ok2, "event cluster failed: {stderr}");
    let hashes1 = state_hashes(&out1);
    assert!(!hashes1.is_empty(), "no state-hash line:\n{out1}");
    assert_eq!(hashes1, state_hashes(&out2), "engine moved the cluster state hash");
    let agg = |o: &str| -> String {
        o.lines().find(|l| l.starts_with("aggregate:")).unwrap_or_default().to_owned()
    };
    assert_eq!(agg(&out1), agg(&out2), "engine moved an aggregate number");
}

#[test]
fn serve_gen_rejects_unknown_engine() {
    let (ok, _, stderr) = run(&["serve-gen", "--engine", "sideways"]);
    assert!(!ok);
    assert!(stderr.contains("unknown engine 'sideways' (tick|event)"), "{stderr}");
}

#[test]
fn fidelity_sweep_prints_pareto_and_is_deterministic() {
    let (ok, out1, stderr) = run(&["fidelity-sweep"]);
    assert!(ok, "fidelity-sweep failed: {stderr}");
    for needle in [
        "Fidelity Pareto",
        "stream len",
        "sigma(units)",
        "logit RMS(est)",
        "est accuracy",
        "time factor",
        "QoS-tiered serving",
        "acc p10",
    ] {
        assert!(out1.contains(needle), "missing '{needle}':\n{out1}");
    }
    // Both the 16 and 256 design points appear (the acceptance sweep
    // range), and nothing degenerates.
    assert!(out1.lines().any(|l| l.trim_start().starts_with("16 ")), "no 16-bit row:\n{out1}");
    assert!(out1.lines().any(|l| l.trim_start().starts_with("256 ")), "no 256-bit row:\n{out1}");
    assert!(!out1.contains("NaN"));
    // Pure analytic + seeded serving: byte-identical across runs.
    let (ok2, out2, _) = run(&["fidelity-sweep"]);
    assert!(ok2);
    assert_eq!(out1, out2, "fidelity-sweep must be deterministic");
}

#[test]
fn serve_gen_qos_prints_accuracy_and_is_deterministic() {
    let args = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "1",
        "--sessions",
        "6",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
        "--qos",
        "bronze",
    ];
    let (ok, out1, stderr) = run(&args);
    assert!(ok, "serve-gen --qos failed: {stderr}");
    for needle in ["qos bronze", "est accuracy", "p10", "acc p10"] {
        assert!(out1.contains(needle), "missing '{needle}':\n{out1}");
    }
    let (ok2, out2, _) = run(&args);
    assert!(ok2);
    assert_eq!(out1, out2, "serve-gen --qos must be deterministic");

    // The mixed assignment is accepted too and labels the header.
    let (ok, out, stderr) = run(&[
        "serve-gen", "--sessions", "6", "--batch", "4", "--model", "Transformer-base", "--qos",
        "mix",
    ]);
    assert!(ok, "mix failed: {stderr}");
    assert!(out.contains("qos mix"), "{out}");
}

#[test]
fn serve_gen_rejects_unknown_qos_tier() {
    let (ok, _, stderr) = run(&["serve-gen", "--qos", "platinum"]);
    assert!(!ok);
    assert!(
        stderr.contains("unknown QoS tier 'platinum' (gold|silver|bronze|mix)"),
        "{stderr}"
    );
}

#[test]
fn serve_gen_rejects_unknown_scenario() {
    let (ok, _, stderr) = run(&["serve-gen", "--scenario", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("unknown scenario"), "{stderr}");
}

/// A per-test temp path for trace files (pid + tag keeps parallel test
/// threads and concurrent CI jobs from colliding).
fn temp_trace(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("artemis-smoke-{tag}-{}.jsonl", std::process::id()))
}

#[test]
fn serve_gen_trace_roundtrips_through_trace_report() {
    let path = temp_trace("roundtrip");
    let p = path.to_str().unwrap();
    let args = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "1",
        "--sessions",
        "6",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
        "--qos",
        "mix",
        "--trace",
        p,
    ];
    let (ok, out, stderr) = run(&args);
    assert!(ok, "traced serve-gen failed: {stderr}");
    assert!(out.contains("trace: wrote"), "{out}");
    assert!(out.contains("schema v1"), "{out}");
    assert!(out.contains("slo-verdict gold="), "{out}");
    // The file is versioned JSONL: header first, footer last.
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 3, "suspiciously short trace:\n{text}");
    assert!(lines[0].contains("\"t\":\"header\"") && lines[0].contains("\"schema\":1"), "{text}");
    assert!(lines[lines.len() - 1].contains("\"t\":\"footer\""), "{text}");
    assert!(!text.contains("NaN") && !text.contains("inf"), "non-finite JSON:\n{text}");
    // trace-report replays the file into tables plus the verdict line.
    let (ok, report, stderr) = run(&["trace-report", p, "--top", "3"]);
    assert!(ok, "trace-report failed: {stderr}");
    for needle in ["Trace summary", "SLO verdicts", "Worst sessions", "slo-verdict gold="] {
        assert!(report.contains(needle), "missing '{needle}':\n{report}");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_gen_trace_files_are_byte_identical_across_runs_and_engines() {
    // Determinism holds at the artifact level too: same seed, same
    // bytes on disk — run-to-run and tick-vs-event.
    let base = [
        "serve-gen",
        "--scenario",
        "burst",
        "--seed",
        "3",
        "--sessions",
        "6",
        "--batch",
        "3",
        "--model",
        "Transformer-base",
        "--qos",
        "mix",
        "--trace",
    ];
    let mut texts = Vec::new();
    for (tag, engine) in [("eng-a", "tick"), ("eng-b", "tick"), ("eng-c", "event")] {
        let path = temp_trace(tag);
        let p = path.to_str().unwrap().to_owned();
        let mut args: Vec<&str> = base.to_vec();
        args.push(&p);
        args.extend(["--engine", engine]);
        let (ok, _, stderr) = run(&args);
        assert!(ok, "traced serve-gen ({tag}) failed: {stderr}");
        texts.push(std::fs::read_to_string(&path).unwrap());
        std::fs::remove_file(&path).ok();
    }
    assert_eq!(texts[0], texts[1], "same-seed reruns must write identical traces");
    assert_eq!(texts[0], texts[2], "tick vs event must write identical traces");
}

#[test]
fn serve_gen_zero_sessions_writes_a_valid_empty_trace() {
    // Regression: `--sessions 0 --trace` used to skip the trace file
    // entirely; it must write header + slo + footer with no NaN.
    let path = temp_trace("empty");
    let p = path.to_str().unwrap();
    let (ok, out, stderr) = run(&["serve-gen", "--sessions", "0", "--trace", p]);
    assert!(ok, "empty traced serve-gen failed: {stderr}");
    assert!(out.contains("empty trace (0 sessions)"), "{out}");
    assert!(out.contains("trace: wrote"), "{out}");
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "empty trace should be header+slo+footer:\n{text}");
    assert!(lines[0].contains("\"t\":\"header\""), "{text}");
    assert!(lines[1].contains("\"t\":\"slo\""), "{text}");
    assert!(lines[2].contains("\"t\":\"footer\""), "{text}");
    assert!(!text.contains("NaN") && !text.contains("inf"), "non-finite JSON:\n{text}");
    let (ok, report, stderr) = run(&["trace-report", p]);
    assert!(ok, "trace-report on empty trace failed: {stderr}");
    assert!(report.contains("slo-verdict gold=no-data"), "{report}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_gen_rejects_bad_telemetry_flags() {
    let (ok, _, stderr) = run(&["serve-gen", "--trace", "/tmp/x.jsonl", "--slo", "garbage"]);
    assert!(!ok);
    assert!(stderr.contains("bad --slo"), "{stderr}");
    let (ok, _, stderr) = run(&["serve-gen", "--trace", "/tmp/x.jsonl", "--trace-window", "0"]);
    assert!(!ok);
    assert!(stderr.contains("--trace-window must be a positive"), "{stderr}");
}

#[test]
fn trace_report_rejects_missing_args_and_files() {
    let (ok, _, stderr) = run(&["trace-report"]);
    assert!(!ok);
    assert!(stderr.contains("usage: artemis trace-report"), "{stderr}");
    let (ok, _, stderr) = run(&["trace-report", "/definitely/not/a/file.jsonl"]);
    assert!(!ok, "nonexistent trace file must fail: {stderr}");
}

#[test]
fn unknown_command_exits_nonzero() {
    let (ok, _, stderr) = run(&["not-a-command"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn serve_gen_rejects_misspelled_flags_with_did_you_mean() {
    // Regression: `--polcy spf` used to be silently ignored (the run
    // proceeded under the default fifo); unknown flags now reject,
    // with a closest-match hint when one is near.
    let (ok, _, stderr) = run(&["serve-gen", "--polcy", "spf"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag '--polcy'"), "{stderr}");
    assert!(stderr.contains("did you mean '--policy'?"), "{stderr}");
    // No close neighbour: point at help instead of guessing.
    let (ok, _, stderr) = run(&["serve-gen", "--frobnicate", "1"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag '--frobnicate'"), "{stderr}");
    assert!(stderr.contains("artemis help"), "{stderr}");
}

#[test]
fn serve_gen_rejects_session_counts_beyond_the_cap() {
    // Counts past 2^32 are refused up front with a canonical error
    // that estimates the materialized-trace memory, instead of letting
    // the run drift into an unserviceable allocation.
    let (ok, _, stderr) = run(&["serve-gen", "--sessions", "4294967297"]);
    assert!(!ok, "a 2^32+1 session request must be rejected");
    assert!(stderr.contains("exceeds the 2^32 session cap"), "{stderr}");
    assert!(stderr.contains("GiB"), "error should estimate memory: {stderr}");
}

#[test]
fn bench_scale_writes_artifact_and_gates_on_engine_equality() {
    // Tiny ascending points (>= 10x apart, so the sub-linear-memory
    // ratio gate is exercised) through both engines; the JSON artifact
    // must land with one row per point.
    let path = std::env::temp_dir().join(format!("artemis-scale-{}.json", std::process::id()));
    let p = path.to_str().unwrap();
    let (ok, out, stderr) =
        run(&["bench-scale", "--sessions", "4,40", "--seed", "1", "--out", p]);
    assert!(ok, "bench-scale failed: {stderr}");
    for needle in ["bench-scale chat 4 sessions", "bench-scale chat 40 sessions", "state-hash"] {
        assert!(out.contains(needle), "missing '{needle}':\n{out}");
    }
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"suite\": \"serve_scale_stream\""), "{text}");
    assert!(text.contains("\"sessions\": 4") && text.contains("\"sessions\": 40"), "{text}");
    std::fs::remove_file(&path).ok();
    // Non-ascending points are rejected (peak RSS is a process-wide
    // high-water mark; descending points would read as flat).
    let (ok, _, stderr) = run(&["bench-scale", "--sessions", "40,4"]);
    assert!(!ok);
    assert!(stderr.contains("strictly ascending"), "{stderr}");
}

#[test]
fn serve_gen_spec_file_is_equivalent_to_flags() {
    // A --spec file and the equivalent flag vector are one request:
    // the outputs must be byte-identical.  Explicit flags layer over
    // the file's fields.
    let path = std::env::temp_dir().join(format!("artemis-spec-{}.json", std::process::id()));
    let spec_json = concat!(
        r#"{"kind":"artemis-serve-spec","version":1,"scenario":"chat","#,
        r#""seed":"1","sessions":6,"model":"Transformer-base","batch":4}"#
    );
    std::fs::write(&path, spec_json).unwrap();
    let p = path.to_str().unwrap();
    let flags = [
        "serve-gen",
        "--scenario",
        "chat",
        "--seed",
        "1",
        "--sessions",
        "6",
        "--batch",
        "4",
        "--model",
        "Transformer-base",
    ];
    let (ok1, out1, stderr) = run(&flags);
    assert!(ok1, "flag serve-gen failed: {stderr}");
    let (ok2, out2, stderr) = run(&["serve-gen", "--spec", p]);
    assert!(ok2, "spec serve-gen failed: {stderr}");
    assert_eq!(out1, out2, "--spec FILE must reproduce the flag run byte-for-byte");
    // An explicit flag wins over the file value.
    let (ok3, out3, stderr) = run(&["serve-gen", "--spec", p, "--batch", "2"]);
    assert!(ok3, "spec+flag serve-gen failed: {stderr}");
    assert!(out3.contains("batch 2"), "flag must override the spec file:\n{out3}");
    std::fs::remove_file(&path).ok();
}
