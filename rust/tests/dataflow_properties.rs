//! Property tests over sharding and the inter-bank network.

use artemis::config::{HbmConfig, StackLinkParams};
use artemis::dataflow::{
    layer_assignment, stack_groups, token_shards, LayerRange, RingNetwork, Shard, StackLink,
};
use artemis::util::prop::check;

#[test]
fn prop_token_shards_partition() {
    check(500, 0x30, |g| {
        let n = g.u64_below(5000);
        let k = 1 + g.u64_below(256);
        let shards = token_shards(n, k);
        assert_eq!(shards.len(), k as usize);
        // exact cover, in order, no overlap
        let mut next = 0u64;
        for s in &shards {
            assert_eq!(s.start, next, "n={n} k={k}");
            assert!(s.end >= s.start);
            next = s.end;
        }
        assert_eq!(next, n);
        // balance within 1
        let lens: Vec<u64> = shards.iter().map(Shard::len).collect();
        let min = lens.iter().min().unwrap();
        let max = lens.iter().max().unwrap();
        assert!(max - min <= 1, "n={n} k={k} lens span {min}..{max}");
    });
}

#[test]
fn prop_layer_assignment_total_banks_conserved() {
    check(300, 0x31, |g| {
        let layers = 1 + g.u64_below(64);
        let banks = 1 + g.u64_below(128);
        let a = layer_assignment(layers, banks);
        assert_eq!(a.len(), layers as usize);
        for group in &a {
            assert!(!group.is_empty());
            for &b in group {
                assert!(b < banks);
            }
        }
        if layers < banks {
            // groups partition the banks
            let total: usize = a.iter().map(Vec::len).sum();
            assert_eq!(total as u64, banks);
        }
    });
}

#[test]
fn prop_token_shards_edge_cases() {
    // N < K leaves exactly K - N empty shards; K = 1 owns everything.
    check(300, 0x35, |g| {
        let k = 2 + g.u64_below(64);
        let n = g.u64_below(k); // strictly fewer tokens than banks
        let shards = token_shards(n, k);
        assert_eq!(shards.iter().filter(|s| !s.is_empty()).count() as u64, n);
        assert_eq!(shards.iter().filter(|s| s.is_empty()).count() as u64, k - n);
        let single = token_shards(n, 1);
        assert_eq!(single.len(), 1);
        assert_eq!(single[0].len(), n);
    });
}

#[test]
fn prop_stack_groups_partition_layers() {
    // The stack-group generalization: every layer owned by exactly one
    // stack, ranges contiguous and balanced, empties only when D > L.
    check(300, 0x36, |g| {
        let layers = 1 + g.u64_below(64);
        let stacks = 1 + g.u64_below(16);
        let groups = stack_groups(layers, stacks);
        assert_eq!(groups.len(), stacks as usize);
        let mut next = 0u64;
        for grp in &groups {
            assert_eq!(grp.start, next, "layers={layers} stacks={stacks}");
            next = grp.end;
        }
        assert_eq!(next, layers);
        let lens: Vec<u64> = groups.iter().map(LayerRange::len).collect();
        let (min, max) = (*lens.iter().min().unwrap(), *lens.iter().max().unwrap());
        assert!(max - min <= 1);
        let empties = lens.iter().filter(|&&l| l == 0).count() as u64;
        assert_eq!(empties, stacks.saturating_sub(layers));
    });
}

#[test]
fn prop_stack_link_latency_monotone_in_payload() {
    let link = StackLink::new(&StackLinkParams::default());
    check(200, 0x37, |g| {
        let bits = 1 + g.u64_below(1_000_000);
        let small = link.hop(bits);
        let big = link.hop(2 * bits);
        assert!(big.latency_ns >= small.latency_ns);
        assert_eq!(big.bits_moved, 2 * small.bits_moved);
        // Fixed hop cost dominates tiny payloads; beats dominate bulk.
        assert!(small.latency_ns >= StackLinkParams::default().hop_ns);
    });
}

#[test]
fn prop_allgather_latency_scales_linearly_in_shard() {
    let hbm = HbmConfig::default();
    let net = RingNetwork::new(&hbm);
    check(200, 0x32, |g| {
        let bits = 256 * (1 + g.u64_below(1000));
        let c1 = net.allgather(bits);
        let c2 = net.allgather(2 * bits);
        assert!((c2.latency_ns / c1.latency_ns - 2.0).abs() < 0.01);
        assert_eq!(c2.bits_moved, 2 * c1.bits_moved);
    });
}

#[test]
fn prop_allgather_energy_conserves_bits() {
    let hbm = HbmConfig::default();
    let net = RingNetwork::new(&hbm);
    let k = hbm.banks_total();
    check(200, 0x33, |g| {
        let bits = 1 + g.u64_below(100_000);
        let c = net.allgather(bits);
        // every bank must receive K-1 foreign shards
        assert_eq!(c.bits_moved, k * (k - 1) * bits);
    });
}

#[test]
fn prop_broadcast_never_beats_single_transfer() {
    let hbm = HbmConfig::default();
    let net = RingNetwork::new(&hbm);
    check(200, 0x34, |g| {
        let bits = 1 + g.u64_below(1_000_000);
        let bcast = net.broadcast(bits);
        let single = net.shared_bus(bits);
        assert!(bcast.latency_ns >= single.latency_ns);
        assert!(bcast.bits_moved >= single.bits_moved);
    });
}
