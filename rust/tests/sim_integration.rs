//! Integration tests across the simulator stack: config -> workload ->
//! sim -> reports, plus failure-injection on configs.

use artemis::config::{ArtemisConfig, ModelZoo};
use artemis::dataflow::{Dataflow, Pipelining};
use artemis::report;
use artemis::sim::{simulate, SimOptions};
use artemis::util::prop::check;
use artemis::xfmr::build_workload;

fn all_policies() -> Vec<SimOptions> {
    vec![
        SimOptions { dataflow: Dataflow::Layer, pipelining: Pipelining::Off },
        SimOptions { dataflow: Dataflow::Layer, pipelining: Pipelining::On },
        SimOptions { dataflow: Dataflow::Token, pipelining: Pipelining::Off },
        SimOptions { dataflow: Dataflow::Token, pipelining: Pipelining::On },
    ]
}

#[test]
fn every_model_every_policy_is_finite_and_positive() {
    let cfg = ArtemisConfig::default();
    for m in ModelZoo::all() {
        let w = build_workload(&m);
        for opts in all_policies() {
            let r = simulate(&cfg, &w, opts);
            assert!(r.total_ns.is_finite() && r.total_ns > 0.0, "{} {}", m.name, r.policy);
            assert!(r.total_energy_pj() > 0.0);
            assert!(r.gops() > 0.0);
            assert!(r.phases.mac_ns > 0.0);
        }
    }
}

#[test]
fn pipelining_never_hurts_any_model() {
    let cfg = ArtemisConfig::default();
    for m in ModelZoo::all() {
        let w = build_workload(&m);
        for df in [Dataflow::Layer, Dataflow::Token] {
            let np = simulate(&cfg, &w, SimOptions { dataflow: df, pipelining: Pipelining::Off });
            let pp = simulate(&cfg, &w, SimOptions { dataflow: df, pipelining: Pipelining::On });
            assert!(pp.total_ns <= np.total_ns * 1.0001, "{} {df:?}", m.name);
        }
    }
}

#[test]
fn fig8_shape_token_11x_pipelining_40pct() {
    // The paper's Fig. 8 averages: token ~11x over layer, pipelining
    // ~43-50%.  Enforce the same decade.
    let cfg = ArtemisConfig::default();
    let mut token_speedups = Vec::new();
    let mut pp_speedups = Vec::new();
    for m in ModelZoo::all() {
        let w = build_workload(&m);
        let opt = |dataflow, pipelining| SimOptions { dataflow, pipelining };
        let l_np = simulate(&cfg, &w, opt(Dataflow::Layer, Pipelining::Off));
        let t_np = simulate(&cfg, &w, opt(Dataflow::Token, Pipelining::Off));
        let t_pp = simulate(&cfg, &w, opt(Dataflow::Token, Pipelining::On));
        token_speedups.push(l_np.total_ns / t_np.total_ns);
        pp_speedups.push(t_np.total_ns / t_pp.total_ns);
    }
    let avg_token = token_speedups.iter().sum::<f64>() / token_speedups.len() as f64;
    let avg_pp = pp_speedups.iter().sum::<f64>() / pp_speedups.len() as f64;
    assert!((4.0..30.0).contains(&avg_token), "token speedup avg {avg_token}");
    assert!((1.2..2.2).contains(&avg_pp), "pipelining speedup avg {avg_pp}");
}

#[test]
fn artemis_beats_all_baselines_on_all_models() {
    let cfg = ArtemisConfig::default();
    for m in ModelZoo::all() {
        let w = build_workload(&m);
        let r = simulate(&cfg, &w, SimOptions::artemis());
        for p in artemis::baselines::comparison_platforms() {
            assert!(
                r.total_ns < p.latency_ns(&w),
                "{}: ARTEMIS {:.2}ms vs {} {:.2}ms",
                m.name,
                r.latency_ms(),
                p.name,
                p.latency_ns(&w) * 1e-6
            );
            assert!(r.total_energy_pj() < p.energy_pj(&w));
        }
    }
}

#[test]
fn speedup_vs_cpu_in_paper_decade() {
    // Paper: 1230x average over CPU.  Same decade required.
    let cfg = ArtemisConfig::default();
    let cpu = &artemis::baselines::comparison_platforms()[0];
    let mut ratios = Vec::new();
    for m in ModelZoo::all() {
        let w = build_workload(&m);
        let r = simulate(&cfg, &w, SimOptions::artemis());
        ratios.push(cpu.latency_ns(&w) / r.total_ns);
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!((300.0..5000.0).contains(&avg), "avg CPU speedup {avg}");
}

#[test]
fn prop_random_configs_stay_consistent() {
    // Failure injection: random (valid) geometries must never produce
    // NaNs, zero latencies, or budget violations.
    check(40, 0x40, |g| {
        let mut cfg = ArtemisConfig::default();
        cfg.hbm.stacks = 1 + g.u64_below(4);
        cfg.hbm.banks_per_channel = 1 + g.u64_below(8);
        cfg.hbm.subarrays_per_bank = 2 * (1 + g.u64_below(128));
        cfg.momcap.max_accumulations = 1 + g.u64_below(100) as u32;
        cfg.power_budget_w = g.f64_in(20.0, 300.0);
        cfg.sign_split_passes = g.bool();
        let m = ModelZoo::bert_base();
        let w = build_workload(&m);
        let r = simulate(&cfg, &w, SimOptions::artemis());
        assert!(r.total_ns.is_finite() && r.total_ns > 0.0);
        assert!(r.total_energy_pj().is_finite() && r.total_energy_pj() > 0.0);
        assert!(r.avg_power_w() <= cfg.power_budget_w * 1.3,
            "power {} over budget {}", r.avg_power_w(), cfg.power_budget_w);
    });
}

#[test]
fn config_json_roundtrip_preserves_sim_results() {
    let cfg = ArtemisConfig::with_stacks(2);
    let cfg2 = ArtemisConfig::from_json(&cfg.to_json()).unwrap();
    let w = build_workload(&ModelZoo::bert_base());
    // power budget isn't in the JSON subset scaled by with_stacks, so
    // set it equal before comparing
    let mut cfg2 = cfg2;
    cfg2.power_budget_w = cfg.power_budget_w;
    cfg2.static_power_w = cfg.static_power_w;
    let a = simulate(&cfg, &w, SimOptions::artemis());
    let b = simulate(&cfg2, &w, SimOptions::artemis());
    assert!((a.total_ns - b.total_ns).abs() < 1e-6);
}

#[test]
fn all_report_tables_render() {
    let cfg = ArtemisConfig::default();
    for t in [
        report::fig2(&cfg),
        report::tab3(&cfg),
        report::tab5(&cfg),
        report::fig7(),
        report::fig8(&cfg),
        report::fig9(&cfg),
        report::fig10(&cfg),
        report::fig11(&cfg),
        report::fig12(),
        report::micro(&cfg),
    ] {
        let text = t.render();
        assert!(text.lines().count() >= 4, "table too small:\n{text}");
        assert!(!text.contains("NaN"), "NaN leaked into report:\n{text}");
    }
}

#[test]
fn drisa_fig2_shape_holds() {
    let cfg = ArtemisConfig::default();
    for m in ModelZoo::all() {
        let w = build_workload(&m);
        let f = artemis::baselines::drisa_matmul_fraction(&cfg, &w);
        assert!(f > 0.9, "{}: {f}", m.name);
        assert!(f < 1.0);
    }
}

#[test]
fn runtime_rejects_corrupt_manifest() {
    use artemis::runtime::ArtifactRegistry;
    let dir = std::env::temp_dir().join("artemis_corrupt_manifest_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(ArtifactRegistry::open(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"configs": {}}"#).unwrap();
    assert!(ArtifactRegistry::open(&dir).is_err(), "missing artifacts key");
}

#[test]
fn runtime_errors_on_unknown_artifact_and_missing_file() {
    use artemis::runtime::ArtifactRegistry;
    let dir = std::env::temp_dir().join("artemis_missing_artifact_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": {"ghost": {"path": "ghost.hlo.txt", "inputs": [[2, 2]], "dtype": "f32"}},
            "configs": {}}"#,
    )
    .unwrap();
    let mut reg = ArtifactRegistry::open(&dir).expect("manifest parses");
    assert!(reg.load("nope").is_err(), "unknown name");
    assert!(reg.load("ghost").is_err(), "file absent");
}

#[test]
fn prop_json_roundtrip_fuzz() {
    use artemis::util::json::Json;
    check(200, 0x50, |g| {
        // build a random JSON value, print it, reparse, compare
        fn build(g: &mut artemis::util::prop::Gen, depth: usize) -> Json {
            match if depth > 2 { g.u64_below(4) } else { g.u64_below(6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}-\"q\"\n", g.u64_below(1000))),
                4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| build(g, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..g.usize_in(0, 4))
                        .map(|i| (format!("k{i}"), build(g, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = build(g, 0);
        let reparsed = Json::parse(&v.pretty()).expect("own output parses");
        assert_eq!(v, reparsed);
    });
}

#[test]
fn decode_steps_monotone_in_context() {
    use artemis::xfmr::decode_step_workload;
    let cfg = ArtemisConfig::default();
    let m = ModelZoo::opt_350();
    let mut last = 0.0;
    for ctx in [128u64, 512, 2048, 8192] {
        let w = decode_step_workload(&m, ctx);
        let r = simulate(&cfg, &w, SimOptions::artemis());
        assert!(r.total_ns >= last, "ctx={ctx}");
        last = r.total_ns;
    }
}

#[test]
fn remap_penalty_appears_in_sim_latency() {
    let mut cfg = ArtemisConfig::default();
    cfg.hbm.subarrays_per_bank = 8; // force weight remapping for BERT
    let m = ModelZoo::bert_base();
    let w = build_workload(&m);
    let small = simulate(&cfg, &w, SimOptions::artemis());
    let cap = artemis::dataflow::capacity_report(&cfg, &m);
    assert!(cap.mapping_rounds > 1);
    assert!(small.phases.relayout_ns >= cap.remap_latency_ns * 0.99);
}
