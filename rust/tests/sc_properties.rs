//! Property tests over the stochastic-computing substrate.

use artemis::sc::{
    correlation_encode, sc_multiply, sc_multiply_signed, tcu_encode, u_to_b_priority,
    BitStream, SignedCode, STREAM_LEN,
};
use artemis::util::prop::check;

#[test]
fn prop_multiply_equals_trunc_toward_zero() {
    check(2000, 0xA, |g| {
        let a = g.code();
        let b = g.code();
        let got = sc_multiply_signed(SignedCode::from_i32(a), SignedCode::from_i32(b));
        let want = (a as i64 * b as i64) / 128; // rust / truncates toward zero
        assert_eq!(got as i64, want, "a={a} b={b}");
    });
}

#[test]
fn prop_multiply_monotone_in_each_operand() {
    check(500, 0xB, |g| {
        let a = g.u64_below(128) as u32;
        let b = g.u64_below(129) as u32;
        assert!(sc_multiply(a, b) <= sc_multiply(a + 1, b), "a={a} b={b}");
        assert!(sc_multiply(b, a) <= sc_multiply(b, a + 1), "a={a} b={b}");
    });
}

#[test]
fn prop_encodings_preserve_popcount() {
    check(500, 0xC, |g| {
        let m = g.u64_below(129) as u32;
        assert_eq!(tcu_encode(m).popcount(), m);
        assert_eq!(correlation_encode(m).popcount(), m);
    });
}

#[test]
fn prop_priority_decoder_inverts_tcu_encode() {
    check(500, 0xD, |g| {
        let m = g.u64_below(129) as u32;
        assert_eq!(u_to_b_priority(&tcu_encode(m)).unwrap(), m);
    });
}

#[test]
fn prop_and_popcount_never_exceeds_operands() {
    check(1000, 0xE, |g| {
        let a = g.u64_below(129) as u32;
        let b = g.u64_below(129) as u32;
        let p = correlation_encode(a).and(&tcu_encode(b)).popcount();
        assert!(p <= a.min(b), "a={a} b={b} p={p}");
    });
}

#[test]
fn prop_multiply_identity_and_zero() {
    check(300, 0xF, |g| {
        let a = g.u64_below(129) as u32;
        assert_eq!(sc_multiply(a, STREAM_LEN), a, "x * 1.0 == x");
        assert_eq!(sc_multiply(a, 0), 0);
        assert_eq!(sc_multiply(0, a), 0);
    });
}

#[test]
fn prop_stream_set_get_consistent() {
    check(500, 0x10, |g| {
        let mut s = BitStream::ZERO;
        let mut reference = [false; 128];
        for _ in 0..40 {
            let i = g.u64_below(128) as u32;
            let v = g.bool();
            s.set(i, v);
            reference[i as usize] = v;
        }
        for (i, &want) in reference.iter().enumerate() {
            assert_eq!(s.get(i as u32), want, "bit {i}");
        }
        assert_eq!(s.popcount() as usize, reference.iter().filter(|&&b| b).count());
    });
}

#[test]
fn prop_distributivity_error_bounded() {
    // SC products lose at most 1 unit each vs the exact scaled product,
    // so a k-term dot drifts at most k units below exact.
    check(300, 0x11, |g| {
        let k = g.usize_in(1, 64);
        let mut sc_sum = 0i64;
        let mut exact_scaled = 0.0f64;
        for _ in 0..k {
            let a = g.u64_below(129) as u32;
            let b = g.u64_below(129) as u32;
            sc_sum += sc_multiply(a, b) as i64;
            exact_scaled += (a as f64) * (b as f64) / 128.0;
        }
        let err = exact_scaled - sc_sum as f64;
        assert!((0.0..k as f64).contains(&err) || err.abs() < 1e-9, "k={k} err={err}");
    });
}
