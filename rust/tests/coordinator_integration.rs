//! Coordinator integration: serving through the active runtime backend
//! (PJRT artifacts when available, the built-in reference backend
//! otherwise) with batching, multi-producer channels, and functional
//! scoring.
//!
//! Uses the fp32/q8 models (fast compiles); the q8sc variant is
//! exercised by `examples/end_to_end.rs`.

use artemis::config::ArtemisConfig;
use artemis::coordinator::{synth_eval_batch, Coordinator, InferenceRequest};
use artemis::runtime::ArtifactRegistry;
use artemis::util::XorShift64;

fn registry() -> Option<ArtifactRegistry> {
    match ArtifactRegistry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping coordinator tests (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn serves_all_requests_exactly_once() {
    let Some(mut reg) = registry() else { return };
    let cfg = ArtemisConfig::default();
    let mut coord = Coordinator::new(&mut reg, &cfg, "fp32").expect("coordinator");
    let seq = coord.seq_len();
    let mut rng = XorShift64::new(1);
    let n = 37; // deliberately not a batch multiple
    let requests: Vec<InferenceRequest> = (0..n)
        .map(|id| InferenceRequest {
            id,
            tokens: (0..seq).map(|_| rng.below(32) as f32).collect(),
            enqueued_ns: 0,
        })
        .collect();
    let (responses, stats) = coord.serve_all(requests).expect("serve");
    assert_eq!(responses.len(), n as usize);
    assert_eq!(stats.requests, n);
    // every id exactly once
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n as usize);
    // padding only on the last batch
    assert_eq!(stats.padded_rows as usize, (8 - (n as usize % 8)) % 8);
    assert!(stats.sim_total_ns > 0.0);
    assert!(stats.sim_total_pj > 0.0);
}

#[test]
fn trained_model_beats_chance_through_serving_path() {
    let Some(mut reg) = registry() else { return };
    let cfg = ArtemisConfig::default();
    let mut coord = Coordinator::new(&mut reg, &cfg, "fp32").expect("coordinator");
    let seq = coord.seq_len();
    let mut rng = XorShift64::new(9);
    let mut labels = Vec::new();
    let requests: Vec<InferenceRequest> = (0..256u64)
        .map(|id| {
            let tokens: Vec<f32> = (0..seq).map(|_| rng.below(32) as f32).collect();
            let ones = tokens.iter().filter(|&&t| t == 1.0).count();
            let twos = tokens.iter().filter(|&&t| t == 2.0).count();
            labels.push(usize::from(ones > twos));
            InferenceRequest { id, tokens, enqueued_ns: 0 }
        })
        .collect();
    let (responses, _) = coord.serve_all(requests).expect("serve");
    let correct = responses
        .iter()
        .filter(|r| r.predicted == labels[r.id as usize])
        .count();
    let acc = correct as f64 / responses.len() as f64;
    assert!(acc > 0.7, "serving-path accuracy {acc}");
}

#[test]
fn producers_on_other_threads() {
    let Some(mut reg) = registry() else { return };
    let cfg = ArtemisConfig::default();
    let mut coord = Coordinator::new(&mut reg, &cfg, "fp32").expect("coordinator");
    let seq = coord.seq_len();
    let (tx, rx) = std::sync::mpsc::channel();
    let producers: Vec<_> = (0..4u64)
        .map(|p| {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut rng = XorShift64::new(p + 100);
                for i in 0..16u64 {
                    tx.send(InferenceRequest {
                        id: p * 16 + i,
                        tokens: (0..seq).map(|_| rng.below(32) as f32).collect(),
                        enqueued_ns: 0,
                    })
                    .unwrap();
                }
            })
        })
        .collect();
    drop(tx);
    let (responses, stats) = coord.serve(rx).expect("serve");
    for p in producers {
        p.join().unwrap();
    }
    assert_eq!(responses.len(), 64);
    assert_eq!(stats.batches, 8);
    assert_eq!(stats.padded_rows, 0);
}

#[test]
fn q8_and_fp32_mostly_agree_on_predictions() {
    let Some(mut reg) = registry() else { return };
    let cfg = ArtemisConfig::default();
    let tiny = reg.tiny_config().unwrap().clone();

    let mut rng = XorShift64::new(0x51);
    let (tokens, _) = synth_eval_batch(&mut rng, tiny.batch, tiny.seq_len, tiny.vocab);

    let fp32 = reg.load("tiny_fp32").unwrap();
    let q8 = reg.load("tiny_q8").unwrap();
    let l32 = fp32.run_f32(&[tokens.clone()]).unwrap();
    let l8 = q8.run_f32(&[tokens]).unwrap();
    let mut agree = 0;
    for i in 0..tiny.batch {
        let am = |l: &[f32]| {
            let row = &l[i * tiny.n_classes..(i + 1) * tiny.n_classes];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        agree += usize::from(am(&l32) == am(&l8));
    }
    assert!(agree >= tiny.batch - 1, "q8 disagreed on {} of {}", tiny.batch - agree, tiny.batch);
}

#[test]
fn token_placement_covers_sequence() {
    let Some(mut reg) = registry() else { return };
    let cfg = ArtemisConfig::default();
    let mut coord = Coordinator::new(&mut reg, &cfg, "fp32").expect("coordinator");
    let seq = coord.seq_len();
    let requests: Vec<InferenceRequest> = (0..8u64)
        .map(|id| InferenceRequest {
            id,
            tokens: vec![0.0; seq],
            enqueued_ns: 0,
        })
        .collect();
    let (_, stats) = coord.serve_all(requests).expect("serve");
    let total_tokens: u64 = stats.tokens_per_bank.iter().sum();
    assert_eq!(total_tokens, seq as u64 * 8);
}

#[test]
fn router_dispatches_mixed_variants() {
    use artemis::coordinator::{RoutedRequest, Router};
    let Some(mut reg) = registry() else { return };
    let cfg = ArtemisConfig::default();
    // fp32 + q8 only (q8sc compiles take minutes; exercised elsewhere).
    let mut router = Router::new(&mut reg, &cfg, &["fp32", "q8"]).expect("router");
    let seq = router.seq_len();
    let mut rng = XorShift64::new(77);
    let requests: Vec<RoutedRequest> = (0..48u64)
        .map(|id| RoutedRequest {
            variant: if id % 3 == 0 { "q8".into() } else { "fp32".into() },
            request: InferenceRequest {
                id,
                tokens: (0..seq).map(|_| rng.below(32) as f32).collect(),
                enqueued_ns: 0,
            },
        })
        .collect();
    let (responses, outcomes) = router.route_all(requests).expect("route");
    assert_eq!(responses.len(), 48);
    assert_eq!(outcomes.len(), 2);
    let by_variant: std::collections::HashMap<_, _> = outcomes
        .iter()
        .map(|o| (o.variant.as_str(), o.stats.requests))
        .collect();
    assert_eq!(by_variant["q8"], 16);
    assert_eq!(by_variant["fp32"], 32);
    for o in &outcomes {
        assert!(o.exec_percentiles.p50 <= o.exec_percentiles.p99);
        assert!(o.exec_percentiles.max > 0);
    }
}

#[test]
fn router_rejects_unknown_variant() {
    use artemis::coordinator::{RoutedRequest, Router};
    let Some(mut reg) = registry() else { return };
    let cfg = ArtemisConfig::default();
    let mut router = Router::new(&mut reg, &cfg, &["fp32"]).expect("router");
    let bad = vec![RoutedRequest {
        variant: "int4".into(),
        request: InferenceRequest { id: 0, tokens: vec![0.0; router.seq_len()], enqueued_ns: 0 },
    }];
    assert!(router.route_all(bad).is_err());
}
