//! Differential suite for the streaming serving core (DESIGN.md
//! §Scale-out memory accounting).
//!
//! The lazy arrival stream ([`Scenario::stream`]) plus the slab-backed
//! session store must be *observably indistinguishable* from the
//! legacy materialize-everything path: same one-u64 state hash, same
//! retirement-order sessions digest — across scenarios, clock-advance
//! engines, static vs continuous batching, cluster placements, thread
//! counts, and a mid-stream snapshot/restore of a streamed campaign.

use artemis::cluster::{run_cluster, run_cluster_stream, Campaign};
use artemis::config::{ArtemisConfig, ClusterConfig, EngineStrategy, ModelZoo, Placement};
use artemis::serve::{
    run_continuous_engine, run_continuous_stream, run_static, run_static_stream, Policy,
    QosAssignment, RoutePolicy, Scenario, SchedulerConfig,
};

/// Small fast scenario on the 2-layer Transformer-base with mixed QoS
/// tiers in flight (the engine_equivalence idiom).
fn fast_scenario(name: &str, sessions: usize) -> Scenario {
    let mut sc = Scenario::by_name(name).expect("built-in scenario").with_sessions(sessions);
    sc.model = ModelZoo::transformer_base();
    sc.with_qos(QosAssignment::Mixed)
}

#[test]
fn streaming_arrivals_match_materialized_reports_bit_for_bit() {
    let cfg = ArtemisConfig::default();
    let seed = 7u64;
    for name in ["chat", "summarize", "burst"] {
        let sc = fast_scenario(name, 12);
        let trace = sc.generate(seed);
        for policy in [Policy::Fifo, Policy::ShortestPromptFirst] {
            let sched = SchedulerConfig { max_batch: 4, policy };
            for engine in [EngineStrategy::Tick, EngineStrategy::Event] {
                let mat = run_continuous_engine(&cfg, &sc.model, &trace, &sched, engine);
                let st =
                    run_continuous_stream(&cfg, &sc.model, sc.stream(seed), &sched, engine);
                assert_eq!(
                    mat.state_hash(),
                    st.state_hash(),
                    "{name}/{policy:?}/{engine}: streamed continuous hash drifted"
                );
                assert_eq!(
                    mat.sessions_digest, st.sessions_digest,
                    "{name}/{policy:?}/{engine}: sessions digest drifted"
                );
            }
        }
        let mat = run_static(&cfg, &sc.model, &trace, 4);
        let st = run_static_stream(&cfg, &sc.model, sc.stream(seed), 4);
        assert_eq!(mat.state_hash(), st.state_hash(), "{name}: streamed static hash drifted");
    }
}

#[test]
fn streaming_cluster_matches_materialized_across_placements_and_threads() {
    let cfg = ArtemisConfig::default();
    let seed = 1u64;
    let sc = fast_scenario("chat", 12);
    let trace = sc.generate(seed);
    let sched = SchedulerConfig { max_batch: 4, policy: Policy::Fifo };
    for placement in [Placement::DataParallel, Placement::PipelineParallel] {
        for threads in [1usize, 2] {
            let cl = ClusterConfig::new(2, placement).with_threads(threads);
            let mat =
                run_cluster(&cfg, &sc.model, &trace, &cl, &sched, RoutePolicy::LeastLoaded, true);
            let st = run_cluster_stream(
                &cfg,
                &sc.model,
                sc.stream(seed),
                &cl,
                &sched,
                RoutePolicy::LeastLoaded,
                true,
            );
            assert_eq!(
                mat.state_hash(),
                st.state_hash(),
                "{placement}/threads {threads}: streamed cluster hash drifted"
            );
        }
    }
}

#[test]
fn streamed_campaign_snapshot_restore_lands_on_the_uninterrupted_hash() {
    let cfg = ArtemisConfig::default();
    let seed = 5u64;
    let sc = fast_scenario("burst", 10);
    let sched = SchedulerConfig { max_batch: 3, policy: Policy::Fifo };
    let cl = ClusterConfig::new(2, Placement::DataParallel).with_threads(1);
    let build = |stream| {
        Campaign::new_streamed(
            &cfg,
            &sc.model,
            stream,
            &cl,
            &sched,
            RoutePolicy::RoundRobin,
            true,
            None,
        )
    };

    // Reference: the uninterrupted streamed run.
    let mut reference = build(sc.stream(seed));
    while reference.step(16) {}
    let (ref_report, _) = reference.finish(None);

    // Interrupted: step partway (some arrivals routed, none drained),
    // snapshot, restore into a *fresh* campaign, finish both.
    let mut interrupted = build(sc.stream(seed));
    for _ in 0..4 {
        assert!(interrupted.step(16), "campaign finished before the snapshot point");
    }
    let snap = interrupted.snapshot_json();
    let mut restored = build(sc.stream(seed));
    restored.restore_json(&snap).expect("restore streamed snapshot");
    while interrupted.step(16) {}
    while restored.step(16) {}
    let (a, _) = interrupted.finish(None);
    let (b, _) = restored.finish(None);
    assert_eq!(
        a.state_hash(),
        ref_report.state_hash(),
        "interrupted streamed campaign diverged from the uninterrupted run"
    );
    assert_eq!(
        b.state_hash(),
        ref_report.state_hash(),
        "restored streamed campaign diverged from the uninterrupted run"
    );
}
