//! Property tests for the serializable [`ServeSpec`] request API: a
//! randomized valid `serve-gen` flag vector must parse into a spec
//! that survives the JSON round-trip bit-exactly (args → spec → JSON →
//! spec identity), and layering the same flags over the parsed spec
//! must be idempotent.

use artemis::serve::ServeSpec;
use artemis::util::json::Json;
use artemis::util::prop::{check, Gen};

const SCENARIOS: [&str; 4] = ["chat", "summarize", "burst", "long_itl"];
const MODELS: [&str; 5] = ["Transformer-base", "BERT-base", "ALBERT-base", "ViT-base", "OPT-350"];
const POLICIES: [&str; 2] = ["fifo", "spf"];
const ENGINES: [&str; 2] = ["tick", "event"];
const QOS: [&str; 4] = ["gold", "silver", "bronze", "mix"];
const PLACEMENTS: [&str; 2] = ["dp", "pp"];
const ROUTES: [&str; 3] = ["rr", "ll", "kv"];
const SLOS: [&str; 3] = ["default", "gold:ttft=100ms,itl=10ms", "gold:ttft=50ms;bronze:ttft=2s"];
const WINDOWS: [&str; 3] = ["50", "100", "250.5"];

/// One random valid flag vector: every flag independently present or
/// absent, every value drawn from its legal domain.
fn gen_args(g: &mut Gen) -> Vec<String> {
    let mut args: Vec<String> = vec!["serve-gen".into()];
    let flag = |args: &mut Vec<String>, name: &str, value: String| {
        args.push(name.into());
        args.push(value);
    };
    if g.bool() {
        flag(&mut args, "--scenario", SCENARIOS[g.usize_in(0, 3)].into());
    }
    if g.bool() {
        // Full-width seeds: the decimal-string JSON path must carry
        // values the f64 number path would round.
        flag(&mut args, "--seed", g.u64_below(u64::MAX).to_string());
    }
    if g.bool() {
        flag(&mut args, "--sessions", g.usize_in(0, 40).to_string());
    }
    if g.bool() {
        flag(&mut args, "--model", MODELS[g.usize_in(0, 4)].into());
    }
    if g.bool() {
        flag(&mut args, "--batch", g.usize_in(1, 16).to_string());
    }
    if g.bool() {
        flag(&mut args, "--policy", POLICIES[g.usize_in(0, 1)].into());
    }
    if g.bool() {
        flag(&mut args, "--engine", ENGINES[g.usize_in(0, 1)].into());
    }
    if g.bool() {
        flag(&mut args, "--qos", QOS[g.usize_in(0, 3)].into());
    }
    if g.bool() {
        flag(&mut args, "--trace", format!("trace-{}.jsonl", g.u64_below(1000)));
        if g.bool() {
            flag(&mut args, "--slo", SLOS[g.usize_in(0, 2)].into());
        }
        if g.bool() {
            flag(&mut args, "--trace-window", WINDOWS[g.usize_in(0, 2)].into());
        }
    }
    if g.bool() {
        // Cluster section: any one of these flags switches it on.
        if g.bool() {
            flag(&mut args, "--stacks", (g.u64_below(6) + 1).to_string());
        }
        if g.bool() {
            flag(&mut args, "--placement", PLACEMENTS[g.usize_in(0, 1)].into());
        }
        if g.bool() {
            flag(&mut args, "--route", ROUTES[g.usize_in(0, 2)].into());
        }
        if g.bool() {
            flag(&mut args, "--threads", g.usize_in(0, 8).to_string());
        }
        if g.bool() {
            args.push("--no-cost-cache".into());
        }
    }
    args
}

#[test]
fn random_flag_vectors_round_trip_through_json_bit_exactly() {
    check(200, 0x5EC5, |g| {
        let args = gen_args(g);
        let spec = ServeSpec::from_args(&args)
            .unwrap_or_else(|e| panic!("valid args rejected ({e}): {args:?}"));
        let j = spec.to_json();
        let spec2 = ServeSpec::from_json(&j)
            .unwrap_or_else(|e| panic!("own JSON rejected ({e}): {}", j.compact()));
        assert_eq!(spec, spec2, "spec drifted through Json values: {}", j.compact());
        // Through the text form too: parse(compact) is the wire path
        // the daemon and `--spec FILE` use.
        let parsed = Json::parse(&j.compact()).expect("spec JSON must parse");
        let spec3 = ServeSpec::from_json(&parsed).expect("parsed spec JSON must convert");
        assert_eq!(spec, spec3, "spec drifted through the text round-trip");
        assert_eq!(
            j.compact(),
            spec3.to_json().compact(),
            "serialized form must be a fixed point"
        );
    });
}

#[test]
fn relayering_the_same_flags_is_idempotent() {
    check(200, 0xA11A, |g| {
        let args = gen_args(g);
        let spec = ServeSpec::from_args(&args).expect("valid args");
        // Same flags over the spec they produced: nothing moves.
        let again = ServeSpec::from_args_over(spec.clone(), &args).expect("relayer");
        assert_eq!(spec, again, "relayering the same flags moved a field: {args:?}");
        // No flags at all (the daemon's validate() path): nothing moves.
        let validated = ServeSpec::from_args_over(spec.clone(), &[]).expect("validate");
        assert_eq!(spec, validated, "validation moved a field: {args:?}");
    });
}

#[test]
fn specs_validate_and_resolve_consistently() {
    check(100, 0xBEEF, |g| {
        let args = gen_args(g);
        let spec = ServeSpec::from_args(&args).expect("valid args");
        spec.validate().expect("parsed specs must validate");
        let resolved = spec.resolve().expect("parsed specs must resolve");
        assert!(resolved.batch >= 1, "resolved batch must be positive");
        // The resolved scenario honours the overrides carried in the
        // spec (sessions is the one numeric override loadgen echoes).
        if let Some(n) = spec.sessions {
            assert_eq!(resolved.scenario.sessions, n, "sessions override lost");
        }
        if let Some(model) = &spec.model {
            assert!(
                resolved.scenario.model.name.eq_ignore_ascii_case(model),
                "model override lost: {} vs {model}",
                resolved.scenario.model.name
            );
        }
    });
}
