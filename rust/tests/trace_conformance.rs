//! Telemetry trace conformance suite (DESIGN.md §Telemetry).
//!
//! The three invariants the telemetry layer promises, asserted
//! end-to-end against live serve runs:
//!
//! 1. **Determinism** — the same seed emits *byte-identical* JSONL
//!    across `EngineStrategy::{Tick,Event}`, driver thread counts, and
//!    cost-cache on/off.
//! 2. **Hash neutrality** — enabling telemetry never moves a report's
//!    state hash.
//! 3. **Exactness** — span energies sum to the report's total energy,
//!    and a single-tier run's final SLO percentiles reproduce the
//!    report's histogram percentiles bit-for-bit.
//!
//! Plus the schema gate: `tests/golden/trace_schema.json` pins the
//! per-record-type key sets; any record-shape drift fails here until
//! the schema version and fixture are bumped together.

use artemis::cluster::{run_cluster, run_cluster_traced};
use artemis::config::{ArtemisConfig, ClusterConfig, EngineStrategy, ModelZoo, Placement, SloSpec};
use artemis::serve::{
    run_continuous_engine, run_continuous_traced, Policy, QosAssignment, RoutePolicy, Scenario,
    SchedulerConfig, ServeGenReport,
};
use artemis::telemetry::{parse_trace, MemSink, Trace, TraceConfig, TraceMeta, SCHEMA_VERSION};
use artemis::util::json::Json;

/// A fast scenario: the 2-layer model keeps per-tick simulation cheap.
fn small_scenario(n: usize) -> Scenario {
    let mut sc = Scenario::chat().with_sessions(n);
    sc.model = ModelZoo::transformer_base();
    sc
}

fn meta_for(sc: &Scenario, seed: u64, sessions: usize) -> TraceMeta {
    TraceMeta {
        scenario: sc.name.to_string(),
        model: sc.model.name.clone(),
        seed: Some(seed),
        sessions: sessions as u64,
        qos: sc.qos.to_string(),
    }
}

/// One traced single-replica run; returns the report and the trace.
fn traced_single(
    sc: &Scenario,
    seed: u64,
    engine: EngineStrategy,
    tc: &TraceConfig,
) -> (ServeGenReport, Trace) {
    let cfg = ArtemisConfig::default();
    let trace = sc.generate(seed);
    let sched = SchedulerConfig::for_scenario(sc, Policy::Fifo);
    let meta = meta_for(sc, seed, trace.len());
    run_continuous_traced(&cfg, &sc.model, &trace, &sched, engine, tc, &meta)
}

fn lines_of(doc: &Trace) -> Vec<String> {
    let mut sink = MemSink::default();
    doc.emit(&mut sink);
    sink.lines
}

fn keys_of(j: &Json) -> Vec<String> {
    j.as_obj().expect("record is an object").keys().cloned().collect()
}

fn fixture_keys(j: &Json, name: &str) -> Vec<String> {
    j.get("records")
        .and_then(|r| r.get(name))
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("fixture missing record list '{name}'"))
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect()
}

#[test]
fn schema_fixture_gates_record_shape_drift() {
    let path = format!("{}/tests/golden/trace_schema.json", env!("CARGO_MANIFEST_DIR"));
    let fixture = Json::parse(&std::fs::read_to_string(&path).expect("schema fixture"))
        .expect("fixture parses");
    assert_eq!(
        fixture.get("schema").and_then(Json::as_u64),
        Some(SCHEMA_VERSION),
        "fixture schema version out of step — bump fixture and SCHEMA_VERSION together"
    );

    let sc = small_scenario(8).with_qos(QosAssignment::parse("mix").unwrap());
    let (_, doc) = traced_single(&sc, 1, EngineStrategy::Tick, &TraceConfig::default());
    let parsed = parse_trace(&lines_of(&doc).join("\n")).unwrap();

    assert_eq!(keys_of(&parsed.header), fixture_keys(&fixture, "header"), "header drift");
    for (tier, spec) in parsed.header.get("slo").unwrap().as_obj().unwrap() {
        assert_eq!(keys_of(spec), fixture_keys(&fixture, "header_slo_tier"), "slo[{tier}]");
    }
    assert!(!parsed.spans.is_empty() && !parsed.windows.is_empty());
    for s in &parsed.spans {
        assert_eq!(keys_of(s), fixture_keys(&fixture, "span"), "span drift");
    }
    for w in &parsed.windows {
        assert_eq!(keys_of(w), fixture_keys(&fixture, "window"), "window drift");
        for (tier, snap) in w.get("tiers").unwrap().as_obj().unwrap() {
            assert_eq!(keys_of(snap), fixture_keys(&fixture, "window_tier"), "tiers[{tier}]");
        }
    }
    let slo = parsed.slo.as_ref().expect("slo record");
    assert_eq!(keys_of(slo), fixture_keys(&fixture, "slo"), "slo drift");
    for (tier, v) in slo.get("tiers").unwrap().as_obj().unwrap() {
        assert_eq!(keys_of(v), fixture_keys(&fixture, "slo_tier"), "slo tiers[{tier}]");
    }
    let footer = parsed.footer.as_ref().expect("footer record");
    let optional = fixture_keys(&fixture, "footer_optional");
    let footer_keys: Vec<String> =
        keys_of(footer).into_iter().filter(|k| !optional.contains(k)).collect();
    assert_eq!(footer_keys, fixture_keys(&fixture, "footer"), "footer drift");
}

#[test]
fn traces_are_byte_identical_across_engines() {
    let sc = small_scenario(10).with_qos(QosAssignment::parse("mix").unwrap());
    let tc = TraceConfig::default();
    let (rt, tick) = traced_single(&sc, 1, EngineStrategy::Tick, &tc);
    let (re, event) = traced_single(&sc, 1, EngineStrategy::Event, &tc);
    assert_eq!(rt.state_hash(), re.state_hash());
    assert_eq!(lines_of(&tick), lines_of(&event), "tick and event traces must match bytewise");
}

#[test]
fn cluster_traces_are_byte_identical_across_threads_cache_and_engine() {
    let cfg = ArtemisConfig::default();
    let sc = small_scenario(12).with_qos(QosAssignment::parse("mix").unwrap());
    let trace = sc.generate(1);
    let sched = SchedulerConfig::for_scenario(&sc, Policy::Fifo);
    let tc = TraceConfig::default();
    let meta = meta_for(&sc, 1, trace.len());
    let mut variants: Vec<Vec<String>> = Vec::new();
    for (threads, cached, engine) in [
        (1, true, EngineStrategy::Tick),
        (2, true, EngineStrategy::Tick),
        (1, false, EngineStrategy::Tick),
        (1, true, EngineStrategy::Event),
    ] {
        let cl = ClusterConfig::new(2, Placement::DataParallel)
            .with_threads(threads)
            .with_engine(engine);
        let (_, doc) = run_cluster_traced(
            &cfg,
            &sc.model,
            &trace,
            &cl,
            &sched,
            RoutePolicy::LeastLoaded,
            cached,
            &tc,
            &meta,
        );
        variants.push(lines_of(&doc));
    }
    for (i, v) in variants.iter().enumerate().skip(1) {
        assert_eq!(&variants[0], v, "variant {i} diverged from the reference trace");
    }
}

#[test]
fn telemetry_never_moves_the_state_hash() {
    let cfg = ArtemisConfig::default();
    let sc = small_scenario(8);
    let trace = sc.generate(1);
    let sched = SchedulerConfig::for_scenario(&sc, Policy::Fifo);
    let tc = TraceConfig::default();
    let meta = meta_for(&sc, 1, trace.len());

    let plain = run_continuous_engine(&cfg, &sc.model, &trace, &sched, EngineStrategy::Tick);
    let (traced, _) =
        run_continuous_traced(&cfg, &sc.model, &trace, &sched, EngineStrategy::Tick, &tc, &meta);
    assert_eq!(plain.state_hash(), traced.state_hash(), "single-replica hash moved");

    let cl = ClusterConfig::new(2, Placement::DataParallel);
    let plain = run_cluster(&cfg, &sc.model, &trace, &cl, &sched, RoutePolicy::LeastLoaded, true);
    let (traced, _) = run_cluster_traced(
        &cfg,
        &sc.model,
        &trace,
        &cl,
        &sched,
        RoutePolicy::LeastLoaded,
        true,
        &tc,
        &meta,
    );
    assert_eq!(plain.state_hash(), traced.state_hash(), "cluster hash moved");
}

#[test]
fn span_and_window_energy_sum_to_report_energy() {
    let sc = small_scenario(10);
    let (r, doc) = traced_single(&sc, 1, EngineStrategy::Tick, &TraceConfig::default());
    let span_pj: f64 = doc.spans.iter().map(|s| s.energy_pj()).sum();
    let window_pj: f64 = doc.windows.iter().map(|w| w.energy_pj).sum();
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-30);
    assert!(
        rel(span_pj, r.sim_energy_pj) < 1e-9,
        "span energy {span_pj} != report {}",
        r.sim_energy_pj
    );
    assert!(
        rel(window_pj, r.sim_energy_pj) < 1e-9,
        "window energy {window_pj} != report {}",
        r.sim_energy_pj
    );
    // Every session appears as a span; token counts reconcile too.
    assert_eq!(doc.spans.len(), r.sessions);
    let span_tokens: u64 = doc.spans.iter().map(|s| s.generated).sum();
    assert_eq!(span_tokens, r.total_tokens);
}

#[test]
fn gold_only_run_reproduces_report_percentiles_bitwise() {
    // All sessions on one tier: the trace's final gold histograms see
    // exactly the samples the report's metrics saw, so the running
    // p99s must land on the same bits.
    let sc = small_scenario(8).with_qos(QosAssignment::parse("gold").unwrap());
    let (r, doc) = traced_single(&sc, 1, EngineStrategy::Tick, &TraceConfig::default());
    let gold = doc.slo.tiers[artemis::fidelity::QosTier::Gold.idx()];
    assert_eq!(gold.ttft_p99_ns.to_bits(), r.ttft.p99.to_bits(), "ttft p99 drifted");
    assert_eq!(gold.itl_p99_ns.to_bits(), r.itl.p99.to_bits(), "itl p99 drifted");
    assert_eq!(gold.ttft_n, r.ttft.count);
}

#[test]
fn zero_session_trace_is_valid_and_nan_free() {
    let sc = small_scenario(0);
    let (r, doc) = traced_single(&sc, 1, EngineStrategy::Tick, &TraceConfig::default());
    assert_eq!(r.sessions, 0);
    let lines = lines_of(&doc);
    assert_eq!(lines.len(), 3, "header + slo + footer");
    for l in &lines {
        assert!(!l.contains("NaN") && !l.contains("inf"), "invalid JSON number in {l}");
        Json::parse(l).unwrap_or_else(|e| panic!("bad line {l}: {e}"));
    }
    assert_eq!(doc.slo.verdict_line(), "slo-verdict gold=no-data silver=no-data bronze=no-data");
    let parsed = parse_trace(&lines.join("\n")).unwrap();
    assert_eq!(parsed.schema, SCHEMA_VERSION);
}

#[test]
fn slo_targets_drive_the_verdicts() {
    let sc = small_scenario(8).with_qos(QosAssignment::parse("mix").unwrap());
    let spec = "gold:ttft=1ns,itl=1ns;silver:ttft=1ns,itl=1ns;bronze:ttft=1ns,itl=1ns";
    let tight = TraceConfig { slo: SloSpec::parse(spec).unwrap(), ..TraceConfig::default() };
    let (_, doc) = traced_single(&sc, 1, EngineStrategy::Tick, &tight);
    for v in &doc.slo.tiers {
        if v.ttft_n + v.itl_n > 0 {
            assert_eq!(v.verdict, "fail", "{:?} passed a 1ns target", v.tier);
        }
    }
    // A window that saw violations must burn more than the 1% budget.
    let burned = doc.windows.iter().any(|w| w.tiers.iter().any(|t| t.ttft_burn > 1.0));
    assert!(burned, "no window burned under an unmeetable SLO");

    let spec = "gold:ttft=100s,itl=100s;silver:ttft=100s,itl=100s;bronze:ttft=100s,itl=100s";
    let loose = TraceConfig { slo: SloSpec::parse(spec).unwrap(), ..TraceConfig::default() };
    let (_, doc) = traced_single(&sc, 1, EngineStrategy::Tick, &loose);
    for v in &doc.slo.tiers {
        if v.ttft_n + v.itl_n > 0 {
            assert_eq!(v.verdict, "pass", "{:?} failed a 100s target", v.tier);
        }
    }
}

#[test]
fn tiny_windows_stay_bounded_and_ordered() {
    let sc = small_scenario(16);
    // A 1 us window against a multi-ms makespan forces decimation.
    let tc = TraceConfig { window_ns: 1e3, ..TraceConfig::default() };
    let (_, doc) = traced_single(&sc, 1, EngineStrategy::Tick, &tc);
    assert!(doc.windows.len() <= 512, "window bound violated: {}", doc.windows.len());
    assert!(!doc.windows.is_empty());
    let width = doc.windows[0].end_ns - doc.windows[0].start_ns;
    let k = (width / 1e3).log2();
    assert!(k >= 0.0 && (k - k.round()).abs() < 1e-12, "width {width} is not base*2^k");
    for pair in doc.windows.windows(2) {
        assert!(pair[0].idx < pair[1].idx, "window records out of order");
    }
    for w in &doc.windows {
        assert_eq!(w.start_ns, w.idx as f64 * width);
        assert_eq!(w.end_ns, (w.idx + 1) as f64 * width);
    }
}
