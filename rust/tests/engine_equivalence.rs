//! Differential conformance harness for the clock-advance engines
//! (DESIGN.md §Event-engine).
//!
//! The event-driven engine (`EngineStrategy::Event`) must be
//! *observably indistinguishable* from the reference tick engine: same
//! sessions served, same per-session outcomes, same latency summaries,
//! same occupancy timeline, same energy — bit for bit.  Most tests
//! assert that through the one-u64 `state_hash` digest; this file also
//! keeps the field-by-field oracle proving the hash actually stands in
//! for full report equality (the other suites lean on that).
//!
//! What may legitimately differ between engines: wall-clock time and
//! cost-cache lookup counts (the event engine reuses batch-invariant
//! decode cost pieces) — and the idle-heavy test pins down that the
//! saving is real.

use artemis::cluster::run_cluster;
use artemis::config::{ArtemisConfig, ClusterConfig, EngineStrategy, ModelZoo, Placement};
use artemis::fidelity::ServeFidelity;
use artemis::serve::{
    run_continuous, run_continuous_engine, Coster, KvTracker, Policy, QosAssignment, QosTier,
    ReplicaSim, RoutePolicy, Scenario, SchedulerConfig, ServeGenReport, SessionSpec,
};
use artemis::sim::SimOptions;

/// Small fast scenario on the 2-layer Transformer-base with mixed QoS
/// tiers in flight, so every fidelity path is exercised cheaply.
fn fast_scenario(name: &str, sessions: usize) -> Scenario {
    let mut sc = Scenario::by_name(name).expect("built-in scenario").with_sessions(sessions);
    sc.model = ModelZoo::transformer_base();
    sc.with_qos(QosAssignment::Mixed)
}

/// The field-by-field oracle: every simulated number of two serve
/// reports compared bitwise, including the occupancy timeline and the
/// per-session rows.  Everything asserted here is folded into
/// `state_hash`, which is why the other suites may compare one u64.
fn assert_reports_equal(x: &ServeGenReport, y: &ServeGenReport, what: &str) {
    assert_eq!(x.sessions, y.sessions, "{what}: sessions");
    assert_eq!(x.rejected, y.rejected, "{what}: rejected");
    assert_eq!(x.total_tokens, y.total_tokens, "{what}: tokens");
    assert_eq!(x.ticks, y.ticks, "{what}: ticks");
    assert_eq!(x.makespan_ns.to_bits(), y.makespan_ns.to_bits(), "{what}: makespan");
    assert_eq!(x.sim_energy_pj.to_bits(), y.sim_energy_pj.to_bits(), "{what}: energy");
    assert_eq!(x.mean_batch.to_bits(), y.mean_batch.to_bits(), "{what}: mean batch");
    assert_eq!(x.ttft.p50.to_bits(), y.ttft.p50.to_bits(), "{what}: ttft p50");
    assert_eq!(x.ttft.p95.to_bits(), y.ttft.p95.to_bits(), "{what}: ttft p95");
    assert_eq!(x.ttft.p99.to_bits(), y.ttft.p99.to_bits(), "{what}: ttft p99");
    assert_eq!(x.per_token.mean.to_bits(), y.per_token.mean.to_bits(), "{what}: tok mean");
    assert_eq!(x.per_token.p99.to_bits(), y.per_token.p99.to_bits(), "{what}: tok p99");
    assert_eq!(x.itl.p50.to_bits(), y.itl.p50.to_bits(), "{what}: itl p50");
    assert_eq!(x.itl.p99.to_bits(), y.itl.p99.to_bits(), "{what}: itl p99");
    assert_eq!(x.accuracy.p50.to_bits(), y.accuracy.p50.to_bits(), "{what}: acc p50");
    assert_eq!(x.accuracy.p10.to_bits(), y.accuracy.p10.to_bits(), "{what}: acc p10");
    assert_eq!(x.accuracy.min.to_bits(), y.accuracy.min.to_bits(), "{what}: acc min");
    assert_eq!(x.peak_kv_per_bank, y.peak_kv_per_bank, "{what}: peak kv");
    assert_eq!(x.kv_budget_per_bank, y.kv_budget_per_bank, "{what}: kv budget");
    let (ta, tb) = (x.timeline.samples(), y.timeline.samples());
    assert_eq!(ta.len(), tb.len(), "{what}: timeline length");
    for (a, b) in ta.iter().zip(tb) {
        assert_eq!(a.t_ns.to_bits(), b.t_ns.to_bits(), "{what}: sample time");
        assert_eq!(a.active, b.active, "{what}: sample active");
        assert_eq!(a.queued, b.queued, "{what}: sample queued");
        assert_eq!(a.kv_per_bank_bytes, b.kv_per_bank_bytes, "{what}: sample kv");
    }
    assert_eq!(x.session_reports.len(), y.session_reports.len(), "{what}: report len");
    for (sa, sb) in x.session_reports.iter().zip(&y.session_reports) {
        assert_eq!(sa.id, sb.id, "{what}: session order");
        assert_eq!(sa.prompt, sb.prompt, "{what}: prompt");
        assert_eq!(sa.gen, sb.gen, "{what}: gen");
        assert_eq!(sa.generated, sb.generated, "{what}: generated");
        assert_eq!(sa.rejected, sb.rejected, "{what}: rejected flag");
        assert_eq!(sa.arrival_ns.to_bits(), sb.arrival_ns.to_bits(), "{what}: arrival");
        assert_eq!(sa.ttft_ns.to_bits(), sb.ttft_ns.to_bits(), "{what}: session ttft");
        assert_eq!(sa.finished_ns.to_bits(), sb.finished_ns.to_bits(), "{what}: finish");
        assert_eq!(sa.tier, sb.tier, "{what}: tier");
        assert_eq!(sa.est_accuracy.to_bits(), sb.est_accuracy.to_bits(), "{what}: accuracy");
    }
}

/// The full differential matrix the PR's acceptance names: every
/// scenario x placement x cache x thread-count x 4 seeds, tick vs
/// event, one state-hash comparison each.  On a mismatch the
/// field-by-field diff runs so the failure names the drifting metric.
#[test]
fn event_engine_matches_tick_on_the_full_differential_matrix() {
    let cfg = ArtemisConfig::default();
    for seed in 1..=4u64 {
        for name in ["chat", "summarize", "burst"] {
            let sc = fast_scenario(name, 5);
            let trace = sc.generate(seed);
            let sched = SchedulerConfig { max_batch: 3, policy: Policy::Fifo };
            for placement in [Placement::DataParallel, Placement::PipelineParallel] {
                for cached in [true, false] {
                    for threads in [1usize, 0] {
                        let what = format!(
                            "{name} seed {seed} {placement} cached={cached} threads={threads}"
                        );
                        let base = ClusterConfig::new(2, placement).with_threads(threads);
                        let tick = run_cluster(
                            &cfg,
                            &sc.model,
                            &trace,
                            &base,
                            &sched,
                            RoutePolicy::LeastLoaded,
                            cached,
                        );
                        let event = run_cluster(
                            &cfg,
                            &sc.model,
                            &trace,
                            &base.with_engine(EngineStrategy::Event),
                            &sched,
                            RoutePolicy::LeastLoaded,
                            cached,
                        );
                        if tick.state_hash() != event.state_hash() {
                            assert_reports_equal(&tick.aggregate, &event.aggregate, &what);
                            for (a, b) in tick.per_stack.iter().zip(&event.per_stack) {
                                assert_reports_equal(a, b, &what);
                            }
                            panic!(
                                "{what}: reports field-equal but state hashes differ — \
                                 hash coverage bug"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// The hash's oracle: a run pair that is hash-equal is also full-report
/// equal, and different simulated outcomes get different hashes.
#[test]
fn state_hash_is_a_faithful_stand_in_for_full_report_equality() {
    let cfg = ArtemisConfig::default();
    let sc = fast_scenario("chat", 8);
    let sched = SchedulerConfig { max_batch: 4, policy: Policy::ShortestPromptFirst };
    let trace = sc.generate(1);
    let tick = run_continuous_engine(&cfg, &sc.model, &trace, &sched, EngineStrategy::Tick);
    let event = run_continuous_engine(&cfg, &sc.model, &trace, &sched, EngineStrategy::Event);
    assert_reports_equal(&tick, &event, "oracle");
    assert_eq!(tick.state_hash(), event.state_hash(), "equal reports, equal hashes");
    // Sensitivity: a different seed is a different simulated outcome
    // and must not collide (for these traces, not just probabilistically).
    let other = run_continuous(&cfg, &sc.model, &sc.generate(2), &sched);
    assert_ne!(tick.state_hash(), other.state_hash(), "different runs must differ");
}

/// The wall-clock claim behind the event engine, in counter form: on
/// an idle-heavy deep-queue trace it must reach the *same* state hash
/// while performing strictly fewer costing lookups (DecodeBase reuse:
/// roughly one saved lookup per decode tick on a single-stage stack).
#[test]
fn event_engine_takes_fewer_costing_lookups_when_idle_heavy() {
    let cfg = ArtemisConfig::default();
    let sc = Scenario::long_itl().with_sessions(48);
    let trace = sc.generate(1);
    let sched =
        SchedulerConfig { max_batch: sc.max_batch, policy: Policy::ShortestPromptFirst };
    let run = |engine: EngineStrategy| {
        let cl = ClusterConfig::new(1, Placement::DataParallel).with_engine(engine);
        run_cluster(&cfg, &sc.model, &trace, &cl, &sched, RoutePolicy::LeastLoaded, true)
    };
    let tick = run(EngineStrategy::Tick);
    let event = run(EngineStrategy::Event);
    assert_eq!(tick.state_hash(), event.state_hash(), "engines diverged");
    let (lt, le) = (tick.cache.lookups(), event.cache.lookups());
    assert!(le < lt, "event engine took {le} lookups vs tick {lt} — no reuse happened");
    let saved = lt - le;
    assert!(
        saved >= tick.aggregate.ticks / 2,
        "saved only {saved} lookups over {} decode ticks — reuse barely engaged",
        tick.aggregate.ticks
    );
}

/// Deterministic event ordering: the heap's (time, kind, session-id)
/// total order re-serializes *any* insertion order of the same
/// arrivals — including the simultaneous ones a burst trace is full
/// of — to the same run, verified against the tick-engine reference.
#[test]
fn event_insertion_order_never_changes_the_state_hash() {
    let cfg = ArtemisConfig::default();
    let sc = fast_scenario("burst", 12);
    let sched = SchedulerConfig { max_batch: 3, policy: Policy::Fifo };
    let trace = sc.generate(9);
    let want = run_continuous(&cfg, &sc.model, &trace, &sched).state_hash();

    let run_permuted = |order: &[SessionSpec]| -> u64 {
        let coster =
            Coster::Batched { cfg: &cfg, model: &sc.model, opts: SimOptions::artemis() };
        let mut sim = ReplicaSim::new(
            &sc.model,
            sched.clone(),
            coster,
            KvTracker::new(&cfg, &sc.model),
            sc.model.layers as u64,
            ServeFidelity::for_model(&cfg.fidelity, &sc.model),
            EngineStrategy::Event,
        );
        for spec in order {
            sim.schedule(*spec);
        }
        sim.run_scheduled();
        // The scheme label is excluded from the hash by design, so a
        // hand-driven replica hashes comparably to run_continuous.
        sim.report("permuted".into()).state_hash()
    };

    let mut reversed = trace.clone();
    reversed.reverse();
    let mut rotated = trace.clone();
    rotated.rotate_left(5);
    let half = trace.len() / 2;
    let mut interleaved: Vec<SessionSpec> = Vec::new();
    for i in 0..half {
        interleaved.push(trace[i + half]);
        interleaved.push(trace[i]);
    }
    interleaved.extend_from_slice(&trace[2 * half..]);
    for (label, order) in [
        ("sorted", &trace),
        ("reversed", &reversed),
        ("rotated", &rotated),
        ("interleaved", &interleaved),
    ] {
        assert_eq!(run_permuted(order), want, "{label} insertion order diverged");
    }
}

/// Degenerate traces: empty, single-session, and a hand-built
/// zero-generation-length session (the load generator clamps lengths
/// to >= 1, so the gen == 0 edge needs a literal spec) — identical on
/// both engines, single-machine and cluster paths alike.
#[test]
fn degenerate_traces_hold_on_both_engines() {
    let cfg = ArtemisConfig::default();
    let model = ModelZoo::transformer_base();
    let sched = SchedulerConfig { max_batch: 2, policy: Policy::Fifo };

    for engine in [EngineStrategy::Tick, EngineStrategy::Event] {
        let r = run_continuous_engine(&cfg, &model, &[], &sched, engine);
        assert_eq!(r.sessions, 0, "{engine}");
        assert_eq!(r.total_tokens, 0, "{engine}");
        assert_eq!(r.makespan_ns.to_bits(), 0f64.to_bits(), "{engine}");
        let cl = ClusterConfig::new(2, Placement::DataParallel).with_engine(engine);
        let c = run_cluster(&cfg, &model, &[], &cl, &sched, RoutePolicy::LeastLoaded, true);
        assert_eq!(c.aggregate.sessions, 0, "{engine} cluster");
        assert_eq!(c.aggregate.ticks, 0, "{engine} cluster");
    }
    let empty_tick = run_continuous_engine(&cfg, &model, &[], &sched, EngineStrategy::Tick);
    let empty_event = run_continuous_engine(&cfg, &model, &[], &sched, EngineStrategy::Event);
    assert_eq!(empty_tick.state_hash(), empty_event.state_hash(), "empty trace");

    let one =
        vec![SessionSpec { id: 0, arrival_ns: 0.0, prompt: 16, gen: 4, tier: QosTier::Gold }];
    let t = run_continuous_engine(&cfg, &model, &one, &sched, EngineStrategy::Tick);
    let e = run_continuous_engine(&cfg, &model, &one, &sched, EngineStrategy::Event);
    assert_reports_equal(&t, &e, "single session");
    assert_eq!(t.state_hash(), e.state_hash(), "single session");
    assert_eq!(t.total_tokens, 4);

    let zero = vec![
        SessionSpec { id: 0, arrival_ns: 0.0, prompt: 16, gen: 0, tier: QosTier::Gold },
        SessionSpec { id: 1, arrival_ns: 1000.0, prompt: 8, gen: 3, tier: QosTier::Silver },
    ];
    let t = run_continuous_engine(&cfg, &model, &zero, &sched, EngineStrategy::Tick);
    let e = run_continuous_engine(&cfg, &model, &zero, &sched, EngineStrategy::Event);
    assert_reports_equal(&t, &e, "zero-gen session");
    assert_eq!(t.state_hash(), e.state_hash(), "zero-gen session");
    assert_eq!(t.total_tokens, 3, "only the non-degenerate session generates");
    assert_eq!(t.session_reports[0].generated, 0, "gen == 0 finishes at prefill");
    assert!(!t.session_reports[0].rejected, "gen == 0 is served, not rejected");
    let zc = ClusterConfig::new(2, Placement::DataParallel).with_engine(EngineStrategy::Event);
    let c = run_cluster(&cfg, &model, &zero, &zc, &sched, RoutePolicy::LeastLoaded, true);
    assert_eq!(c.aggregate.total_tokens, 3, "zero-gen session on the cluster path");
}
