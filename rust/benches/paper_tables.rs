//! Benchmark harness: one benchmark per paper table/figure.
//!
//! Each bench regenerates the experiment end-to-end (the same drivers
//! the CLI uses) and times it; run `cargo bench` to produce the numbers
//! recorded in EXPERIMENTS.md.  The offline build has no criterion, so
//! this uses the in-repo harness (`artemis::util::bench`).

use artemis::config::ArtemisConfig;
use artemis::report;
use artemis::util::bench::{bench, keep};

fn main() {
    let cfg = ArtemisConfig::default();
    println!("== paper_tables: regenerate every table/figure ==");

    bench("fig2_drisa_breakdown", || {
        keep(report::fig2(&cfg).render());
    });
    bench("tab3_circuit_overheads", || {
        keep(report::tab3(&cfg).render());
    });
    bench("tab5_calibration_full", || {
        keep(report::tab5(&cfg).render());
    });
    bench("fig7_momcap_staircases", || {
        keep(report::fig7().render());
    });
    bench("fig8_dataflow_sensitivity", || {
        keep(report::fig8(&cfg).render());
    });
    bench("fig9_speedup_sweep", || {
        keep(report::fig9(&cfg).render());
    });
    bench("fig10_energy_sweep", || {
        keep(report::fig10(&cfg).render());
    });
    bench("fig11_efficiency_sweep", || {
        keep(report::fig11(&cfg).render());
    });
    bench("fig12_scalability_sweep", || {
        keep(report::fig12().render());
    });
    bench("micro_headlines", || {
        keep(report::micro(&cfg).render());
    });

    // Table IV needs the artifacts + PJRT; bench it when available.
    match artemis::runtime::ArtifactRegistry::open_default() {
        Ok(mut reg) => {
            // fp32-only scoring loop (q8sc XLA compiles take minutes and
            // are exercised by the end_to_end example instead).
            let model = reg.load("tiny_fp32").expect("artifact");
            let tiny = reg.tiny_config().unwrap().clone();
            let mut rng = artemis::util::XorShift64::new(4);
            let (tokens, _) = artemis::coordinator::synth_eval_batch(
                &mut rng,
                tiny.batch,
                tiny.seq_len,
                tiny.vocab,
            );
            bench("tab4_pjrt_batch_inference", || {
                keep(model.run_f32(&[tokens.clone()]).expect("runs"));
            });
        }
        Err(e) => println!("tab4 bench skipped (run `make artifacts`): {e}"),
    }

    println!("== done ==");
}
