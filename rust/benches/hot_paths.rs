//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! the SC multiply, the encoders, the MOMCAP step, the tile MAC engine,
//! the simulator inner loop, and the full 5-model sweep.

use artemis::analog::MomCap;
use artemis::config::{ArtemisConfig, ModelZoo, MomcapParams};
use artemis::dram::TileMacEngine;
use artemis::sc::{correlation_encode, sc_multiply, tcu_encode, SignedCode};
use artemis::sim::{simulate, SimOptions};
use artemis::util::bench::{bench, keep};
use artemis::util::XorShift64;
use artemis::xfmr::build_workload;

fn main() {
    println!("== hot_paths ==");

    bench("sc_multiply_1k_pairs", || {
        let mut acc = 0u32;
        for a in 0..32u32 {
            for b in 0..32u32 {
                acc = acc.wrapping_add(sc_multiply(keep(a * 4), keep(b * 4)));
            }
        }
        keep(acc);
    });

    bench("tcu_encode_full_range", || {
        for m in 0..=128u32 {
            keep(tcu_encode(m));
        }
    });

    bench("correlation_encode_full_range", || {
        for m in 0..=128u32 {
            keep(correlation_encode(m));
        }
    });

    bench("momcap_window_20_accumulations", || {
        let mut cap = MomCap::new(8.0);
        for _ in 0..20 {
            keep(cap.accumulate(100));
        }
        keep(cap.voltage());
    });

    bench("tile_mac_engine_dot_128", || {
        let mut rng = XorShift64::new(3);
        let a: Vec<SignedCode> = (0..128).map(|_| SignedCode::from_i32(rng.code())).collect();
        let b: Vec<SignedCode> = (0..128).map(|_| SignedCode::from_i32(rng.code())).collect();
        let mut eng = TileMacEngine::new(&MomcapParams::default());
        keep(eng.dot(&a, &b).value);
    });

    let cfg = ArtemisConfig::default();
    let bert = build_workload(&ModelZoo::bert_base());
    bench("simulate_bert_token_pp", || {
        keep(simulate(&cfg, &bert, SimOptions::artemis()).total_ns);
    });

    let opt = build_workload(&ModelZoo::opt_350());
    bench("simulate_opt350_token_pp", || {
        keep(simulate(&cfg, &opt, SimOptions::artemis()).total_ns);
    });

    bench("simulate_all_models_all_policies", || {
        use artemis::dataflow::{Dataflow, Pipelining};
        for m in ModelZoo::all() {
            let w = build_workload(&m);
            for df in [Dataflow::Layer, Dataflow::Token] {
                for pp in [Pipelining::Off, Pipelining::On] {
                    keep(simulate(&cfg, &w, SimOptions { dataflow: df, pipelining: pp }).total_ns);
                }
            }
        }
    });

    println!("== done ==");
}
